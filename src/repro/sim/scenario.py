"""Named serving scenarios, runnable simulated or live.

A ``Scenario`` bundles (topology, workload, serving costs) so the same
experiment can be driven three ways with one metric schema:

* ``run_scenario(sc, mode="sim")`` -- the discrete-event simulator on the
  preset tier parameters (or on fitted ones, via ``calibration=``: the
  same JSON ``CommContext.from_calibration`` loads).
* ``run_scenario(sc, mode="live")`` -- the real ``serve.Engine`` on a
  reduced model, replaying the scenario's first requests on this host and
  reporting the identical p50/p99 keys (parity smoke, not a cluster).
* ``benchmarks/serve_bench.py`` -- sweeps ``rate_scale`` over a scenario
  and writes ``BENCH_serve.json``.

Scenario shapes are REDUCED fanouts of the ``tpu_v5e_3tier`` preset (same
ici/pcie/dcn tier constants, fewer chips) so schedule construction stays
fast enough for CI; pass ``fanout=`` to scale a scenario up.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .cluster import SimCluster
from .engine import Engine
from .serving import ServingConfig, ServingSim
from .workload import WorkloadConfig, generate_trace


@dataclass(frozen=True)
class Scenario:
    """One named serving experiment."""

    name: str
    topology: str = "v5e_3tier"          # preset name, see TOPOLOGY_PRESETS
    fanout: tuple = (2, 4, 2)            # reduced v5e shape (16 procs)
    workload: WorkloadConfig = WorkloadConfig()
    serving: ServingConfig = ServingConfig()
    doc: str = ""

    def with_(self, **kw) -> "Scenario":
        return replace(self, **kw)


SCENARIOS = {
    "smoke": Scenario(
        name="smoke",
        fanout=(2, 4, 2),
        workload=WorkloadConfig(rate=2.0, horizon=10.0, arrival="poisson",
                                mean_prompt_tokens=64, mean_gen_tokens=16,
                                max_prompt_tokens=256, max_gen_tokens=64,
                                seed=0),
        serving=ServingConfig(max_batch=8),
        doc="small Poisson load on a 16-chip 3-tier slice; the CI gate",
    ),
    "steady": Scenario(
        name="steady",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=4.0, horizon=60.0, arrival="poisson",
                                seed=1),
        serving=ServingConfig(max_batch=16),
        doc="steady Poisson load on a 64-chip slice",
    ),
    "diurnal": Scenario(
        name="diurnal",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=4.0, horizon=120.0, arrival="diurnal",
                                diurnal_amp=0.6, diurnal_period=60.0,
                                seed=2),
        serving=ServingConfig(max_batch=16),
        doc="sinusoidally modulated load (daily cycle compressed)",
    ),
    "burst": Scenario(
        name="burst",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=3.0, horizon=60.0, arrival="burst",
                                burst_mult=5.0, seed=3),
        serving=ServingConfig(max_batch=16),
        doc="5x traffic spike over 10% of the horizon",
    ),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r} (known: {sorted(SCENARIOS)})"
        ) from None


def build_cluster(sc: Scenario, calibration=None) -> SimCluster:
    """SimCluster on the scenario's shape, preset or calibrated tiers."""
    engine = Engine()
    if calibration is not None:
        return SimCluster.from_calibration(
            engine, calibration, fanout=sc.fanout,
            kv_capacity_bytes=sc.serving.kv_capacity_bytes,
        )
    return SimCluster.from_preset(
        engine, sc.topology, fanout=sc.fanout,
        kv_capacity_bytes=sc.serving.kv_capacity_bytes,
    )


def unloaded_latency(sc: Scenario, calibration=None) -> float:
    """Latency of one lone mean-sized request -- the tail-gate baseline."""
    cluster = build_cluster(sc, calibration)
    wl = sc.workload
    lone = WorkloadConfig(
        rate=1e-6, horizon=1.0, arrival="poisson", seed=wl.seed,
        mean_prompt_tokens=wl.mean_prompt_tokens,
        mean_gen_tokens=wl.mean_gen_tokens, length_sigma=0.0,
        max_prompt_tokens=wl.max_prompt_tokens,
        max_gen_tokens=wl.max_gen_tokens,
        prompt_quantum=wl.prompt_quantum,
    )
    sim = ServingSim(cluster, sc.serving)
    # replay a single synthetic request directly (no Poisson draw needed)
    from .workload import Request, Trace

    trace = Trace(cfg=lone, requests=[Request(
        rid=0, t_arrival=0.0,
        prompt_tokens=wl.mean_prompt_tokens,
        gen_tokens=wl.mean_gen_tokens,
    )])
    metrics = sim.run(trace)
    return metrics["latency_p50_s"]


def run_scenario(sc: Scenario, mode: str = "sim", *, calibration=None,
                 rate_scale: float = 1.0, max_live_requests: int = 2) -> dict:
    """Run a scenario and return its metrics dict (one schema, both modes)."""
    if mode == "sim":
        wl = replace(sc.workload, rate=sc.workload.rate * rate_scale)
        cluster = build_cluster(sc, calibration)
        trace = generate_trace(wl)
        sim = ServingSim(cluster, sc.serving)
        metrics = sim.run(trace)
        metrics.update(
            scenario=sc.name, mode="sim", rate_scale=rate_scale,
            fanout=list(sc.fanout), n_procs=cluster.topo.n_procs,
            calibrated=calibration is not None,
        )
        return metrics
    if mode == "live":
        return _run_live(sc, rate_scale, max_live_requests)
    raise ValueError(f"mode must be 'sim' or 'live', got {mode!r}")


def _run_live(sc: Scenario, rate_scale: float, max_requests: int) -> dict:
    """Replay the scenario's first requests through the real serve.Engine.

    Imported lazily: the simulator itself never touches jax, so ``sim``
    stays importable on hosts without devices.
    """
    import jax
    import numpy as np

    from ..configs import get_config
    from ..models import lm
    from ..models.config import reduced_for_smoke
    from ..serve.engine import Engine as ServeEngine

    wl = replace(sc.workload, rate=sc.workload.rate * rate_scale)
    trace = generate_trace(wl)
    reqs = trace.requests[:max_requests]
    if not reqs:
        raise ValueError(f"scenario {sc.name!r} generated no requests")
    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32"
    )
    prompt_len = min(max(r.prompt_tokens for r in reqs),
                     wl.max_prompt_tokens, 64)
    gen_len = min(max(r.gen_tokens for r in reqs), wl.max_gen_tokens, 16)
    rng = np.random.default_rng(wl.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (len(reqs), prompt_len), dtype=np.int32
    )
    params = lm.init_params(jax.random.PRNGKey(wl.seed), cfg)
    eng = ServeEngine(cfg, params, max_len=prompt_len + gen_len + 1,
                      seed=wl.seed)
    res = eng.generate(prompts, gen_len)
    from .serving import percentile

    steps = list(res.step_latencies_s)
    latency = res.prefill_s + res.decode_s
    return {
        "scenario": sc.name,
        "mode": "live",
        "rate_scale": rate_scale,
        "n_requests": len(reqs),
        "n_completed": len(reqs),
        "throughput_rps": len(reqs) / latency if latency else 0.0,
        "throughput_tok_s": res.decode_tok_s,
        "latency_p50_s": latency,
        "latency_p99_s": latency,
        "ttft_p50_s": res.prefill_s,
        "ttft_p99_s": res.prefill_s,
        "step_p50_s": percentile(steps, 50),
        "step_p99_s": percentile(steps, 99),
        "n_steps": res.steps,
    }
