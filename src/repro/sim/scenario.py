"""Named serving scenarios, runnable simulated or live.

A ``Scenario`` bundles (topology, workload, serving costs) so the same
experiment can be driven three ways with one metric schema:

* ``run_scenario(sc, mode="sim")`` -- the discrete-event simulator on the
  preset tier parameters (or on fitted ones, via ``calibration=``: the
  same JSON ``CommContext.from_calibration`` loads).
* ``run_scenario(sc, mode="live")`` -- the real ``serve.Engine`` on a
  reduced model, replaying the scenario's first requests on this host and
  reporting the identical p50/p99 keys (parity smoke, not a cluster).
* ``benchmarks/serve_bench.py`` -- sweeps ``rate_scale`` over a scenario
  and writes ``BENCH_serve.json``.

Scenario shapes are REDUCED fanouts of the ``tpu_v5e_3tier`` preset (same
ici/pcie/dcn tier constants, fewer chips) so schedule construction stays
fast enough for CI; pass ``fanout=`` to scale a scenario up.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from .cluster import SimCluster
from .engine import Engine
from .faults import FaultInjector, FaultSpec
from .serving import ServingConfig, ServingSim
from .workload import WorkloadConfig, generate_trace


@dataclass(frozen=True)
class Scenario:
    """One named serving experiment."""

    name: str
    topology: str = "v5e_3tier"          # preset name, see TOPOLOGY_PRESETS
    fanout: tuple = (2, 4, 2)            # reduced v5e shape (16 procs)
    workload: WorkloadConfig = WorkloadConfig()
    serving: ServingConfig = ServingConfig()
    faults: tuple = ()                   # FaultSpecs injected into sim runs
    doc: str = ""

    def with_(self, **kw) -> "Scenario":
        return replace(self, **kw)

    def healthy(self) -> "Scenario":
        """The same experiment with no faults (the comparison baseline)."""
        return replace(self, faults=())


SCENARIOS = {
    "smoke": Scenario(
        name="smoke",
        fanout=(2, 4, 2),
        workload=WorkloadConfig(rate=2.0, horizon=10.0, arrival="poisson",
                                mean_prompt_tokens=64, mean_gen_tokens=16,
                                max_prompt_tokens=256, max_gen_tokens=64,
                                seed=0),
        serving=ServingConfig(max_batch=8),
        doc="small Poisson load on a 16-chip 3-tier slice; the CI gate",
    ),
    "steady": Scenario(
        name="steady",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=4.0, horizon=60.0, arrival="poisson",
                                seed=1),
        serving=ServingConfig(max_batch=16),
        doc="steady Poisson load on a 64-chip slice",
    ),
    "diurnal": Scenario(
        name="diurnal",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=4.0, horizon=120.0, arrival="diurnal",
                                diurnal_amp=0.6, diurnal_period=60.0,
                                seed=2),
        serving=ServingConfig(max_batch=16),
        doc="sinusoidally modulated load (daily cycle compressed)",
    ),
    "burst": Scenario(
        name="burst",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=3.0, horizon=60.0, arrival="burst",
                                burst_mult=5.0, seed=3),
        serving=ServingConfig(max_batch=16),
        doc="5x traffic spike over 10% of the horizon",
    ),
    # -- fault scenarios: same machinery, FaultSpecs armed ---------------
    "kill_recovery": Scenario(
        name="kill_recovery",
        fanout=(2, 4, 2),
        workload=WorkloadConfig(rate=2.0, horizon=10.0, arrival="poisson",
                                mean_prompt_tokens=64, mean_gen_tokens=16,
                                max_prompt_tokens=256, max_gen_tokens=64,
                                seed=0),
        serving=ServingConfig(max_batch=8, restore_overhead_s=0.5),
        faults=(FaultSpec("node_kill", t_start=3.0, node=0),),
        doc="a node dies mid-trace: watchdog detects, the cluster shrinks "
            "to the surviving pod, in-flight requests restart, serving "
            "resumes -- the CI full-loop recovery gate",
    ),
    "brownout_burst": Scenario(
        name="brownout_burst",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=3.0, horizon=60.0, arrival="burst",
                                burst_mult=5.0, seed=3),
        serving=ServingConfig(max_batch=16, max_queue_wait_s=10.0),
        faults=(FaultSpec("link_degrade", t_start=15.0, duration=20.0,
                          tier="dcn", beta_scale=8.0, alpha_add=20e-3),),
        doc="the DCN tier browns out during the burst (1/8 bandwidth, "
            "+20ms latency from congestion); steps re-price on the "
            "degraded topology and requests waiting past 10s are shed "
            "instead of queueing forever",
    ),
    "straggler": Scenario(
        name="straggler",
        fanout=(4, 8, 2),
        workload=WorkloadConfig(rate=4.0, horizon=60.0, arrival="poisson",
                                seed=1),
        serving=ServingConfig(max_batch=16),
        faults=(FaultSpec("straggler", t_start=20.0, duration=20.0,
                          node=0, compute_scale=3.0),),
        doc="one node computes 3x slower for a 20s window; every step "
            "runs at the straggler's pace until the window closes",
    ),
}


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r} (known: {sorted(SCENARIOS)})"
        ) from None


def build_cluster(sc: Scenario, calibration=None) -> SimCluster:
    """SimCluster on the scenario's shape, preset or calibrated tiers."""
    engine = Engine()
    if calibration is not None:
        return SimCluster.from_calibration(
            engine, calibration, fanout=sc.fanout,
            kv_capacity_bytes=sc.serving.kv_capacity_bytes,
        )
    return SimCluster.from_preset(
        engine, sc.topology, fanout=sc.fanout,
        kv_capacity_bytes=sc.serving.kv_capacity_bytes,
    )


def unloaded_latency(sc: Scenario, calibration=None) -> float:
    """Latency of one lone mean-sized request -- the tail-gate baseline."""
    cluster = build_cluster(sc, calibration)
    wl = sc.workload
    lone = WorkloadConfig(
        rate=1e-6, horizon=1.0, arrival="poisson", seed=wl.seed,
        mean_prompt_tokens=wl.mean_prompt_tokens,
        mean_gen_tokens=wl.mean_gen_tokens, length_sigma=0.0,
        max_prompt_tokens=wl.max_prompt_tokens,
        max_gen_tokens=wl.max_gen_tokens,
        prompt_quantum=wl.prompt_quantum,
    )
    sim = ServingSim(cluster, sc.serving)
    # replay a single synthetic request directly (no Poisson draw needed)
    from .workload import Request, Trace

    trace = Trace(cfg=lone, requests=[Request(
        rid=0, t_arrival=0.0,
        prompt_tokens=wl.mean_prompt_tokens,
        gen_tokens=wl.mean_gen_tokens,
    )])
    metrics = sim.run(trace)
    return metrics["latency_p50_s"]


def run_scenario(sc: Scenario, mode: str = "sim", *, calibration=None,
                 rate_scale: float = 1.0, max_live_requests: int = 2,
                 live_timeout_s: float | None = None) -> dict:
    """Run a scenario and return its metrics dict (one schema, both modes)."""
    if mode == "sim":
        wl = replace(sc.workload, rate=sc.workload.rate * rate_scale)
        cluster = build_cluster(sc, calibration)
        trace = generate_trace(wl)
        sim = ServingSim(cluster, sc.serving)
        injector = None
        if sc.faults:
            injector = FaultInjector(cluster.engine, cluster, sc.faults)
            sim.attach_faults(injector)
            injector.arm()
        metrics = sim.run(trace)
        metrics.update(
            scenario=sc.name, mode="sim", rate_scale=rate_scale,
            fanout=list(sc.fanout), n_procs=cluster.topo.n_procs,
            calibrated=calibration is not None,
            faults=injector.schedule() if injector else [],
        )
        return metrics
    if mode == "live":
        return _run_live(sc, rate_scale, max_live_requests, live_timeout_s)
    raise ValueError(f"mode must be 'sim' or 'live', got {mode!r}")


def _run_live(sc: Scenario, rate_scale: float, max_requests: int,
              timeout_s: float | None = None) -> dict:
    """Replay the scenario's first requests through the real serve.Engine.

    Each request is generated independently; with ``timeout_s`` set, a
    generate call that hangs past the deadline FAILS that request (an
    error row in the metrics) instead of wedging the whole replay loop --
    the generation keeps running in its worker thread, but the loop moves
    on and reports.  Imported lazily: the simulator itself never touches
    jax, so ``sim`` stays importable on hosts without devices.
    """
    import concurrent.futures as cf

    import jax
    import numpy as np

    from ..configs import get_config
    from ..models import lm
    from ..models.config import reduced_for_smoke
    from ..serve.engine import Engine as ServeEngine

    wl = replace(sc.workload, rate=sc.workload.rate * rate_scale)
    trace = generate_trace(wl)
    reqs = trace.requests[:max_requests]
    if not reqs:
        raise ValueError(f"scenario {sc.name!r} generated no requests")
    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32"
    )
    prompt_len = min(max(r.prompt_tokens for r in reqs),
                     wl.max_prompt_tokens, 64)
    gen_len = min(max(r.gen_tokens for r in reqs), wl.max_gen_tokens, 16)
    rng = np.random.default_rng(wl.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (len(reqs), prompt_len), dtype=np.int32
    )
    params = lm.init_params(jax.random.PRNGKey(wl.seed), cfg)
    eng = ServeEngine(cfg, params, max_len=prompt_len + gen_len + 1,
                      seed=wl.seed)
    from .serving import percentile

    latencies, ttfts, steps, tok_s = [], [], [], []
    errors = []
    n_steps = 0
    with cf.ThreadPoolExecutor(max_workers=1) as pool:
        for req, prompt in zip(reqs, prompts):
            fut = pool.submit(eng.generate, prompt[None, :], gen_len)
            try:
                res = fut.result(timeout=timeout_s)
            except cf.TimeoutError:
                errors.append({
                    "rid": req.rid,
                    "error": f"generate exceeded {timeout_s:g}s timeout",
                })
                continue
            except Exception as exc:  # noqa: BLE001 -- error row, not a crash
                errors.append({"rid": req.rid, "error": repr(exc)})
                continue
            latencies.append(res.prefill_s + res.decode_s)
            ttfts.append(res.prefill_s)
            steps.extend(res.step_latencies_s)
            tok_s.append(res.decode_tok_s)
            n_steps += res.steps
    wall = sum(latencies)
    return {
        "scenario": sc.name,
        "mode": "live",
        "rate_scale": rate_scale,
        "n_requests": len(reqs),
        "n_completed": len(latencies),
        "n_errors": len(errors),
        "errors": errors,
        "throughput_rps": len(latencies) / wall if wall else 0.0,
        "throughput_tok_s": (
            sum(tok_s) / len(tok_s) if tok_s else 0.0
        ),
        "latency_p50_s": percentile(latencies, 50),
        "latency_p99_s": percentile(latencies, 99),
        "ttft_p50_s": percentile(ttfts, 50),
        "ttft_p99_s": percentile(ttfts, 99),
        "step_p50_s": percentile(steps, 50),
        "step_p99_s": percentile(steps, 99),
        "n_steps": n_steps,
    }
