"""Cluster entities for the event simulator, timed by the calibrated model.

``SimCluster`` wraps a ``ClusterTopology`` (preset or calibrated via the
same loader ``CommContext.from_calibration`` uses) and exposes exactly two
timing primitives to the event layer:

* ``transfer(src, dst, nbytes)`` -- one point-to-point message, charged
  ``tier.transfer_time(nbytes) + assemble_cost`` on the tier separating the
  endpoints, queued through per-``(tier, group)`` Rule-3 link pools sized by
  ``ClusterTopology.degrees`` (0 = unlimited), the same keying
  ``core.simulator.simulate_async`` uses.

* ``collective_time(collective, nbytes)`` -- one whole-group collective,
  priced by building the registry schedule and running the EXACT round
  model ``core.simulator.simulate_rounds`` (not the affine interpolation
  the planner caches), memoized per ``(collective, strategy, nbytes,
  root)``.  This is what makes the simulator's single-collective timing
  equal ``core.simulator.simulate(...)`` bit-for-bit, which the tests
  assert with ``==``.

Nodes carry KV-cache residency so the serving layer can model admission
control: a request is only admitted when its KV footprint is reservable.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..comm import registry
from ..comm.calibrate import CalibrationResult, calibrated_cluster, load_calibration
from ..comm.context import best_plan
from ..core.simulator import simulate_rounds
from ..core.topology import ClusterTopology, topology_preset
from .engine import Engine, LinkPool


class KVCapacityError(RuntimeError):
    """Raised when releasing more KV bytes than are reserved."""


@dataclass
class SimNode:
    """One processor: KV-cache residency accounting for admission control."""

    node_id: int
    kv_capacity_bytes: float = float("inf")
    kv_used_bytes: float = 0.0

    def can_reserve(self, nbytes: float) -> bool:
        return self.kv_used_bytes + nbytes <= self.kv_capacity_bytes

    def reserve(self, nbytes: float) -> bool:
        """Reserve KV bytes; returns False (no side effect) if full."""
        if not self.can_reserve(nbytes):
            return False
        self.kv_used_bytes += nbytes
        return True

    def release(self, nbytes: float) -> None:
        if nbytes > self.kv_used_bytes + 1e-9:
            raise KVCapacityError(
                f"node {self.node_id}: releasing {nbytes} of "
                f"{self.kv_used_bytes} reserved KV bytes"
            )
        self.kv_used_bytes = max(0.0, self.kv_used_bytes - nbytes)


class SimCluster:
    """Nodes + per-tier link pools over a calibrated ``ClusterTopology``."""

    def __init__(
        self,
        engine: Engine,
        topo: ClusterTopology,
        *,
        kv_capacity_bytes: float = float("inf"),
    ) -> None:
        self.engine = engine
        self.topo = topo
        # the pristine baseline the fault layer composes degradations onto
        self.healthy_topo = topo
        self.topo_version = 0
        self.nodes = [
            SimNode(i, kv_capacity_bytes=kv_capacity_bytes)
            for i in range(topo.n_procs)
        ]
        self.dead_nodes: set[int] = set()
        self._compute_scale: dict[int, float] = {}
        self._drops_remaining = 0
        self._drops_until = 0.0
        # Rule-3 pools, lazily created per (tier, group) and direction --
        # the same keying simulate_async uses, but persistent across the
        # whole simulated run instead of per-schedule.
        self._out: dict[tuple[int, int], LinkPool] = {}
        self._in: dict[tuple[int, int], LinkPool] = {}
        # (version, collective, strategy, nbytes, root) -> exact
        # simulate_rounds time; the version component makes every topology
        # change (degrade, shrink, restore) invalidate stale prices.
        self._collective_cache: dict[tuple, float] = {}
        self.bytes_moved = 0.0
        self.n_transfers = 0
        self.n_collectives = 0

    # -- construction ---------------------------------------------------

    @classmethod
    def from_calibration(
        cls,
        engine: Engine,
        source,
        *,
        fanout=None,
        kv_capacity_bytes: float = float("inf"),
    ) -> "SimCluster":
        """Build from a calibration JSON path / dict / CalibrationResult.

        Mirrors ``CommContext.from_calibration``: fitted per-tier
        alpha/beta transplant onto ``fanout`` (defaults to the fitted
        shape), so the simulator and the planner price links identically.
        """
        if isinstance(source, CalibrationResult):
            calib = source
        elif isinstance(source, dict):
            calib = CalibrationResult.from_dict(source)
        else:
            calib = load_calibration(source)
        topo = calibrated_cluster(calib, fanout=fanout)
        return cls(engine, topo, kv_capacity_bytes=kv_capacity_bytes)

    @classmethod
    def from_preset(
        cls,
        engine: Engine,
        name: str,
        *,
        n_machines: int = 2,
        fanout=None,
        kv_capacity_bytes: float = float("inf"),
    ) -> "SimCluster":
        topo = topology_preset(name, n_machines)
        if fanout is not None:
            topo = topo.with_shape(tuple(fanout))
        return cls(engine, topo, kv_capacity_bytes=kv_capacity_bytes)

    # -- fault surface ---------------------------------------------------

    def set_topology(self, topo: ClusterTopology) -> None:
        """Swap in a (typically degraded) topology view.

        Bumps ``topo_version`` so memoized collective prices are stale, and
        resizes existing Rule-3 pools whose tier degree changed, preserving
        in-flight reservations.  ``healthy_topo`` is untouched: the fault
        layer always composes degradations onto the pristine baseline.
        """
        if topo.n_procs != self.topo.n_procs:
            raise ValueError(
                f"set_topology cannot change the proc count "
                f"({self.topo.n_procs} -> {topo.n_procs}); kill nodes or "
                "rebuild the cluster for a shrunk shape"
            )
        self.topo = topo
        self.topo_version += 1
        now = self.engine.now
        for pools in (self._out, self._in):
            for (tix, _), pool in pools.items():
                pool.set_capacity(now, topo.tier_degree(tix))

    def degrade_tier(self, tier: int | str = -1, *, beta_scale: float = 1.0,
                     alpha_add: float = 0.0) -> None:
        """Degrade one tier of the CURRENT topology view (composable)."""
        self.set_topology(
            self.topo.degraded(tier, beta_scale=beta_scale,
                               alpha_add=alpha_add)
        )

    def restore_topology(self) -> None:
        """Back to the healthy baseline (link faults only; nodes separate)."""
        self.set_topology(self.healthy_topo)

    def shrink_to(self, topo: ClusterTopology) -> None:
        """Rebuild onto the surviving shape after node loss (elastic
        recovery).  Unlike ``set_topology`` this DOES change the proc
        count: nodes are recreated (callers re-admit and re-reserve KV),
        the dead set clears (the shrunk shape contains only survivors),
        and ``healthy_topo`` rebases so later link faults compose onto
        the surviving cluster."""
        if topo.n_procs > self.topo.n_procs:
            raise ValueError(
                f"shrink_to grows the cluster ({self.topo.n_procs} -> "
                f"{topo.n_procs}); recovery only shrinks"
            )
        kv_cap = (
            self.nodes[0].kv_capacity_bytes if self.nodes else float("inf")
        )
        self.topo = topo
        self.healthy_topo = topo
        self.topo_version += 1
        self.nodes = [
            SimNode(i, kv_capacity_bytes=kv_cap)
            for i in range(topo.n_procs)
        ]
        self.dead_nodes = set()
        self._compute_scale = {}
        self._out.clear()
        self._in.clear()
        self._collective_cache.clear()

    def kill_node(self, node: int) -> None:
        """Mark a node dead.  Pricing keeps the full-shape schedules until a
        recovery path installs a shrunk topology -- detection is the health
        layer's job, not the cluster's."""
        if not 0 <= node < len(self.nodes):
            raise ValueError(f"no node {node} (have {len(self.nodes)})")
        self.dead_nodes.add(node)
        self.topo_version += 1

    def restore_node(self, node: int) -> None:
        self.dead_nodes.discard(node)
        self.topo_version += 1

    @property
    def alive_nodes(self) -> list[SimNode]:
        return [n for n in self.nodes if n.node_id not in self.dead_nodes]

    @property
    def n_alive(self) -> int:
        return len(self.nodes) - len(self.dead_nodes)

    def set_compute_scale(self, node: int, scale: float) -> None:
        """Per-node compute slowdown (straggler).  1.0 clears it."""
        if scale < 1.0:
            raise ValueError(f"compute scale must be >= 1, got {scale}")
        if scale == 1.0:
            self._compute_scale.pop(node, None)
        else:
            self._compute_scale[node] = float(scale)

    def compute_multiplier(self) -> float:
        """Step-level compute slowdown: data-parallel work finishes with
        the slowest ALIVE participant (a dead straggler stops mattering)."""
        scales = [
            s for n, s in self._compute_scale.items()
            if n not in self.dead_nodes
        ]
        return max(scales, default=1.0)

    def add_drops(self, n: int, until: float) -> None:
        """Arm ``n`` transient collective failures valid until ``until``."""
        now = self.engine.now
        if until < now:
            raise ValueError(f"drop window ends at {until}, now is {now}")
        if self._drops_remaining and self._drops_until >= now:
            self._drops_remaining += int(n)
            self._drops_until = max(self._drops_until, float(until))
        else:
            self._drops_remaining = int(n)
            self._drops_until = float(until)

    def consume_drop(self) -> bool:
        """True (and decrements) if a collective should fail right now."""
        if self._drops_remaining <= 0 or self.engine.now > self._drops_until:
            return False
        self._drops_remaining -= 1
        return True

    # -- point-to-point -------------------------------------------------

    def _pool(self, pools, tix: int, group: int) -> LinkPool:
        key = (tix, group)
        pool = pools.get(key)
        if pool is None:
            pool = pools[key] = LinkPool(self.topo.tier_degree(tix))
        return pool

    def transfer(self, src: int, dst: int, nbytes: float,
                 on_done=None, *args, priority: int = 0) -> float:
        """Start a point-to-point transfer now; returns its end time.

        Duration comes from the calibrated tier separating ``src`` and
        ``dst`` (``alpha + nbytes*beta + assemble_cost``); the start is
        delayed until an egress link of the source group and an ingress
        link of the destination group are simultaneously free.
        """
        if src == dst:
            raise ValueError(f"transfer src == dst == {src}")
        topo = self.topo
        now = self.engine.now
        tix = topo.tier_index(src, dst)
        dur = topo.tiers[tix].transfer_time(nbytes) + topo.assemble_cost
        out = self._pool(self._out, tix, topo.group_of(src, tix))
        inp = self._pool(self._in, tix, topo.group_of(dst, tix))
        start = max(out.next_free(now), inp.next_free(now))
        _, end_o = out.acquire(start, dur)
        _, end_i = inp.acquire(start, dur)
        end = max(end_o, end_i)
        self.bytes_moved += nbytes
        self.n_transfers += 1
        if on_done is not None:
            self.engine.at(end, on_done, *args, priority=priority)
        return end

    # -- collectives ----------------------------------------------------

    def collective_time(
        self,
        collective: str,
        nbytes: float,
        *,
        strategy: str | None = None,
        root: int = 0,
        lossy_ok: bool = False,
    ) -> float:
        """Exact modelled time of one whole-topology collective, seconds.

        Strategy selection (when ``strategy`` is None) uses the planner's
        ``best_plan``; the returned TIME is then recomputed with the exact
        round model on the chosen strategy's schedule, so a simulated
        collective finishes precisely when ``simulate_rounds`` says --
        no affine interpolation error.  Memoized: serving steps reprice
        the same (collective, bytes) pair thousands of times.
        """
        if strategy is None:
            strategy = best_plan(
                self.topo, collective, nbytes, root=root, lossy_ok=lossy_ok
            ).strategy
        key = (self.topo_version, collective, strategy, float(nbytes), root)
        t = self._collective_cache.get(key)
        if t is None:
            spec = registry.get_spec(collective, strategy)
            sched = spec.build_schedule(self.topo, float(nbytes), root=root)
            t = simulate_rounds(sched, check=False)
            self._collective_cache[key] = t
        return t

    def plan_for(self, collective: str, nbytes: float, *, root: int = 0,
                 lossy_ok: bool = False) -> str:
        """The strategy ``collective_time`` would pick right now -- exposed
        so fault scenarios can record when a degradation flips the plan."""
        return best_plan(
            self.topo, collective, nbytes, root=root, lossy_ok=lossy_ok
        ).strategy

    def run_collective(
        self,
        collective: str,
        nbytes: float,
        on_done=None,
        *args,
        strategy: str | None = None,
        root: int = 0,
        lossy_ok: bool = False,
        priority: int = 0,
    ) -> float:
        """Schedule a collective's completion; returns its end time."""
        t = self.collective_time(
            collective, nbytes, strategy=strategy, root=root,
            lossy_ok=lossy_ok,
        )
        end = self.engine.now + t
        self.n_collectives += 1
        if on_done is not None:
            self.engine.at(end, on_done, *args, priority=priority)
        return end

    # -- introspection --------------------------------------------------

    @property
    def kv_used_bytes(self) -> float:
        return sum(n.kv_used_bytes for n in self.nodes)

    def describe(self) -> dict:
        return {
            "n_procs": self.topo.n_procs,
            "fanout": list(self.topo.fanout),
            "tiers": [t.name for t in self.topo.tiers],
            "degrees": list(self.topo.degrees),
            "n_transfers": self.n_transfers,
            "n_collectives": self.n_collectives,
            "bytes_moved": self.bytes_moved,
            "topo_version": self.topo_version,
            "dead_nodes": sorted(self.dead_nodes),
            "compute_multiplier": self.compute_multiplier(),
        }
