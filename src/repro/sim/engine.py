"""Priority-queue discrete-event engine (the ``repro.sim`` core loop).

The analytic simulators in ``repro.core.simulator`` price ONE schedule at a
time; answering cluster-scale questions -- queueing under load, tail latency
of collective-heavy steps, placement choices -- needs many overlapping
requests and transfers evolving over a shared clock.  This module supplies
that clock: a heap-ordered event loop in the style of Helix's
``cluster_simulator.py``, with two hard guarantees the tests pin down:

* **Monotonic time.**  ``Engine.now`` never decreases; scheduling an event
  in the past raises instead of silently reordering history.

* **Deterministic tie-breaking.**  Events fire in ``(time, priority, seq)``
  order where ``seq`` is a monotone insertion counter, so two runs of the
  same seeded scenario produce identical traces.  The engine never reads
  the wall clock -- all randomness lives in the (seeded) workload layer.

``LinkPool`` models a group of ``k`` interchangeable links (the paper's
Rule-3 parallel egress) as next-free times, mirroring the pool bookkeeping
of ``core.simulator.simulate_async`` so the event view and the analytic
view charge link contention identically.
"""

from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field


class SimTimeError(RuntimeError):
    """Raised on attempts to schedule into the past."""


@dataclass(order=False)
class Event:
    """One scheduled callback.  Identity (not value) equality, so cancelled
    events can be tracked through the heap without popping them eagerly."""

    time: float
    priority: int
    seq: int
    fn: object
    args: tuple = ()
    cancelled: bool = field(default=False, compare=False)

    def cancel(self) -> None:
        self.cancelled = True

    @property
    def key(self) -> tuple:
        return (self.time, self.priority, self.seq)


class Engine:
    """Monotonically-ordered event loop with deterministic tie-breaking.

    >>> eng = Engine()
    >>> eng.schedule(1.5, print, "fires at t=1.5")
    >>> eng.run()
    """

    def __init__(self, start_time: float = 0.0) -> None:
        self.now = float(start_time)
        self._heap: list[tuple[tuple, Event]] = []
        self._seq = itertools.count()
        self.n_processed = 0

    def at(self, time: float, fn, *args, priority: int = 0) -> Event:
        """Schedule ``fn(*args)`` at absolute ``time``.

        Same-time events fire in ascending ``priority`` then insertion
        order; scheduling before ``now`` is an error (events may not
        rewrite history).
        """
        if time < self.now:
            raise SimTimeError(
                f"cannot schedule at t={time} (now is {self.now})"
            )
        if not math.isfinite(time):
            raise SimTimeError(f"event time must be finite, got {time}")
        ev = Event(float(time), int(priority), next(self._seq), fn, args)
        heapq.heappush(self._heap, (ev.key, ev))
        return ev

    def schedule(self, delay: float, fn, *args, priority: int = 0) -> Event:
        """Schedule ``fn(*args)`` after ``delay`` seconds (relative)."""
        if delay < 0:
            raise SimTimeError(f"delay must be >= 0, got {delay}")
        return self.at(self.now + delay, fn, *args, priority=priority)

    def peek_time(self) -> float | None:
        """Time of the next pending event (skipping cancelled), or None."""
        while self._heap and self._heap[0][1].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][1].time if self._heap else None

    def step(self) -> bool:
        """Process the single next event.  Returns False when drained."""
        while self._heap:
            _, ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self.now = ev.time
            ev.fn(*ev.args)
            self.n_processed += 1
            return True
        return False

    def run(self, until: float | None = None,
            max_events: int | None = None) -> int:
        """Drain the queue (or stop at ``until`` / after ``max_events``).

        Returns the number of events processed by this call.  With
        ``until``, events at exactly ``until`` still fire and ``now``
        advances to ``until`` even if the queue drains earlier (so
        fixed-horizon scenarios report consistent durations).
        """
        done = 0
        while self._heap if max_events is None else (
            self._heap and done < max_events
        ):
            t = self.peek_time()
            if t is None:
                break
            if until is not None and t > until:
                break
            self.step()
            done += 1
        if until is not None and self.now < until:
            self.now = until
        return done


class LinkPool:
    """``capacity`` interchangeable links as next-free times (0 = unlimited).

    The deterministic assignment rule matches ``simulate_async``: a request
    takes the lowest-index link among the earliest-free.  ``acquire`` is a
    reservation, not an event -- callers know the transfer duration up
    front, so the pool just answers "when can this start, and when is the
    link free again", which keeps contention bookkeeping O(capacity) with
    no extra queue events.
    """

    def __init__(self, capacity: int) -> None:
        self.capacity = int(capacity)
        self._free = [0.0] * self.capacity if self.capacity else None

    def next_free(self, now: float) -> float:
        if not self.capacity:
            return now
        return max(now, min(self._free))

    def set_capacity(self, now: float, capacity: int) -> None:
        """Degrade (or restore) the pool to ``capacity`` links at ``now``.

        Existing reservations are preserved: shrinking keeps the
        *busiest* links' next-free times (the in-flight transfers don't
        vanish, the idle links do); growing adds links free at ``now``.
        Capacity 0 means unlimited, matching the constructor.
        """
        capacity = int(capacity)
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        if capacity == self.capacity:
            return
        if capacity == 0:
            self.capacity, self._free = 0, None
            return
        free = sorted(self._free or [], reverse=True)[:capacity]
        free += [float(now)] * (capacity - len(free))
        self.capacity, self._free = capacity, free

    def fail_until(self, now: float, t_restore: float) -> None:
        """Mark every link unavailable until ``t_restore`` (a hard outage:
        nothing can start before then; in-flight work already reserved past
        ``t_restore`` keeps its later end time)."""
        if t_restore < now:
            raise ValueError(
                f"t_restore {t_restore} is before now {now}"
            )
        if not self.capacity:
            raise ValueError("an unlimited pool cannot fail wholesale")
        self._free = [max(f, float(t_restore)) for f in self._free]

    def acquire(self, now: float, duration: float) -> tuple[float, float]:
        """Reserve one link: returns (start, end) with start >= now."""
        if duration < 0:
            raise ValueError(f"duration must be >= 0, got {duration}")
        if not self.capacity:  # unlimited tier (degrees[l] == 0)
            return now, now + duration
        k = min(range(self.capacity), key=lambda i: self._free[i])
        start = max(now, self._free[k])
        end = start + duration
        self._free[k] = end
        return start, end
