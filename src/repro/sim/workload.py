"""Synthetic request traces: Poisson arrivals with diurnal/burst shaping.

Traces are generated OUTSIDE the event engine from a seeded
``random.Random`` -- the engine itself is randomness-free, so a scenario is
fully determined by ``(seed, workload params, topology)`` and two runs with
the same seed produce identical traces (the determinism test pins this).

Arrival processes:

* ``poisson``  -- homogeneous rate ``lam`` req/s.
* ``diurnal``  -- nonhomogeneous rate ``lam * (1 + amp*sin(2*pi*t/period))``
                  sampled by thinning (Lewis & Shedler): candidates at the
                  peak rate, kept with probability rate(t)/peak.
* ``burst``    -- homogeneous base rate with windows of ``burst_mult`` x
                  intensity, modelling a traffic spike.

Token lengths are integer-quantized lognormal-ish draws (exp of a normal),
clamped to ``[1, max]``; quantizing prompt lengths keeps the set of
distinct per-step collective sizes small, which the serving layer exploits
to memoize exact schedule timings.
"""

from __future__ import annotations

import math
import random
from dataclasses import dataclass, field


@dataclass(frozen=True)
class Request:
    """One inference request: arrival time + prompt/generation lengths."""

    rid: int
    t_arrival: float
    prompt_tokens: int
    gen_tokens: int

    @property
    def total_tokens(self) -> int:
        return self.prompt_tokens + self.gen_tokens


@dataclass(frozen=True)
class WorkloadConfig:
    """Parameters of a synthetic trace (all times in seconds)."""

    rate: float = 1.0                # mean offered load, requests/second
    horizon: float = 60.0            # trace length
    arrival: str = "poisson"         # poisson | diurnal | burst
    seed: int = 0
    # token-length distribution (lognormal-ish, quantized)
    mean_prompt_tokens: int = 128
    mean_gen_tokens: int = 64
    length_sigma: float = 0.4        # 0 => deterministic lengths
    max_prompt_tokens: int = 2048
    max_gen_tokens: int = 1024
    prompt_quantum: int = 16         # round prompts up to a multiple
    # diurnal shaping
    diurnal_amp: float = 0.5         # rate swing fraction, in [0, 1)
    diurnal_period: float = 60.0
    # burst shaping
    burst_mult: float = 4.0
    burst_start: float = 0.25        # fraction of horizon
    burst_frac: float = 0.1          # burst width, fraction of horizon

    def __post_init__(self) -> None:
        if self.rate <= 0 or self.horizon <= 0:
            raise ValueError("rate and horizon must be positive")
        if self.arrival not in ("poisson", "diurnal", "burst"):
            raise ValueError(f"unknown arrival process {self.arrival!r}")
        if not 0.0 <= self.diurnal_amp < 1.0:
            raise ValueError("diurnal_amp must be in [0, 1)")


@dataclass
class Trace:
    """A generated trace plus the config that produced it."""

    cfg: WorkloadConfig
    requests: list = field(default_factory=list)

    @property
    def n_requests(self) -> int:
        return len(self.requests)

    @property
    def offered_rate(self) -> float:
        return self.n_requests / self.cfg.horizon


def _rate_at(cfg: WorkloadConfig, t: float) -> float:
    if cfg.arrival == "poisson":
        return cfg.rate
    if cfg.arrival == "diurnal":
        return cfg.rate * (
            1.0 + cfg.diurnal_amp
            * math.sin(2.0 * math.pi * t / cfg.diurnal_period)
        )
    # burst: base rate with a multiplied window
    t0 = cfg.burst_start * cfg.horizon
    t1 = t0 + cfg.burst_frac * cfg.horizon
    return cfg.rate * (cfg.burst_mult if t0 <= t < t1 else 1.0)


def _peak_rate(cfg: WorkloadConfig) -> float:
    if cfg.arrival == "poisson":
        return cfg.rate
    if cfg.arrival == "diurnal":
        return cfg.rate * (1.0 + cfg.diurnal_amp)
    return cfg.rate * cfg.burst_mult


def _draw_length(rng: random.Random, mean: int, sigma: float,
                 cap: int, quantum: int = 1) -> int:
    """Integer length with the requested mean: exp(N(mu, sigma)) has mean
    exp(mu + sigma^2/2), so mu = ln(mean) - sigma^2/2."""
    if sigma <= 0.0:
        n = mean
    else:
        mu = math.log(mean) - 0.5 * sigma * sigma
        n = int(round(math.exp(rng.gauss(mu, sigma))))
    n = max(1, min(n, cap))
    if quantum > 1:
        n = min(cap, ((n + quantum - 1) // quantum) * quantum)
    return n


def generate_trace(cfg: WorkloadConfig) -> Trace:
    """Sample a full trace by thinning a peak-rate Poisson process."""
    rng = random.Random(cfg.seed)
    peak = _peak_rate(cfg)
    requests = []
    t = 0.0
    rid = 0
    while True:
        t += rng.expovariate(peak)
        if t >= cfg.horizon:
            break
        if rng.random() > _rate_at(cfg, t) / peak:
            continue  # thinned out: candidate exceeds instantaneous rate
        requests.append(Request(
            rid=rid,
            t_arrival=t,
            prompt_tokens=_draw_length(
                rng, cfg.mean_prompt_tokens, cfg.length_sigma,
                cfg.max_prompt_tokens, cfg.prompt_quantum,
            ),
            gen_tokens=_draw_length(
                rng, cfg.mean_gen_tokens, cfg.length_sigma,
                cfg.max_gen_tokens,
            ),
        ))
        rid += 1
    return Trace(cfg=cfg, requests=requests)
