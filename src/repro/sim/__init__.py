"""repro.sim: discrete-event cluster simulator on the calibrated comm model.

Layers (bottom up):

* ``engine``   -- deterministic priority-queue event loop + Rule-3 link
                  pools; no randomness, no wall clock.
* ``cluster``  -- nodes (KV residency) and link pools over a
                  ``ClusterTopology``; collective timing is the EXACT
                  ``core.simulator.simulate_rounds`` round model, memoized.
* ``workload`` -- seeded synthetic traces (Poisson / diurnal / burst).
* ``serving``  -- prefill/decode lifecycles with continuous batching and
                  collective-heavy step costs.
* ``scenario`` -- named experiments runnable simulated or live.

The sim core deliberately avoids jax: it prices cluster-scale serving from
a calibration JSON on any host.  Only ``scenario``'s live mode imports the
real serving engine, lazily.
"""

from .cluster import SimCluster, SimNode
from .engine import Engine, Event, LinkPool, SimTimeError
from .faults import (
    FAULT_KINDS,
    FaultInjector,
    FaultSpec,
    random_faults,
    scale_faults,
)
from .scenario import (
    SCENARIOS,
    Scenario,
    build_cluster,
    get_scenario,
    run_scenario,
    unloaded_latency,
)
from .serving import RequestRecord, ServingConfig, ServingSim, percentile
from .workload import Request, Trace, WorkloadConfig, generate_trace

__all__ = [
    "Engine",
    "Event",
    "LinkPool",
    "SimTimeError",
    "SimCluster",
    "SimNode",
    "FAULT_KINDS",
    "FaultSpec",
    "FaultInjector",
    "random_faults",
    "scale_faults",
    "Request",
    "Trace",
    "WorkloadConfig",
    "generate_trace",
    "ServingConfig",
    "ServingSim",
    "RequestRecord",
    "percentile",
    "Scenario",
    "SCENARIOS",
    "get_scenario",
    "build_cluster",
    "run_scenario",
    "unloaded_latency",
]
