"""Seeded fault injection for the discrete-event cluster simulator.

``FaultSpec`` describes one scheduled fault; ``FaultInjector`` arms a set of
specs on an ``Engine`` + ``SimCluster`` pair, applying each fault at its
start time and reverting it when its window closes.  Four kinds:

* ``link_degrade`` -- one tier's bandwidth is cut (``beta_scale``) and/or
  its startup latency spikes (``alpha_add``) for ``duration`` seconds.  The
  injector swaps a ``ClusterTopology.degraded(...)`` view into the cluster,
  so every collective priced inside the window re-plans and re-prices on
  the degraded parameters -- strategy crossovers can genuinely flip.
* ``straggler`` -- one node computes ``compute_scale`` x slower.  Compute is
  data-parallel across the instance, so a serving/training step runs at the
  pace of its slowest node (``SimCluster.compute_multiplier``).
* ``transient_drop`` -- the next ``n_drops`` collectives inside the window
  fail once each and must be retried (the health layer's bounded backoff
  prices the retries).
* ``node_kill`` -- a node dies at ``t_start`` (default: permanently).  The
  serving layer detects it via its step watchdog and runs the elastic
  recovery path: shrink, re-plan, restore, resume.

Faults compose: the injector recomputes the *effective* topology from the
healthy baseline plus every link fault active at that instant, so
overlapping brownouts stack instead of clobbering each other.

Everything is deterministic.  ``random_faults`` draws a schedule from a
seed (same seed => identical ``FaultSpec`` list, which the tests pin), and
the injector itself adds no randomness at all.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, replace

from .cluster import SimCluster
from .engine import Engine

FAULT_KINDS = ("link_degrade", "straggler", "transient_drop", "node_kill")


@dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  Fields are kind-specific (see module doc)."""

    kind: str
    t_start: float
    duration: float = float("inf")
    # link_degrade
    tier: int | str = -1
    beta_scale: float = 1.0
    alpha_add: float = 0.0
    # straggler / node_kill
    node: int = 0
    compute_scale: float = 1.0
    # transient_drop
    n_drops: int = 1

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (known: {FAULT_KINDS})"
            )
        if self.t_start < 0 or self.duration < 0:
            raise ValueError(
                f"fault times must be >= 0, got t_start={self.t_start} "
                f"duration={self.duration}"
            )
        if self.kind == "link_degrade" and (
            self.beta_scale <= 1.0 and self.alpha_add <= 0.0
        ):
            raise ValueError(
                "link_degrade needs beta_scale > 1 and/or alpha_add > 0"
            )
        if self.kind == "straggler" and self.compute_scale <= 1.0:
            raise ValueError("straggler needs compute_scale > 1")
        if self.kind == "transient_drop" and self.n_drops < 1:
            raise ValueError("transient_drop needs n_drops >= 1")

    @property
    def t_end(self) -> float:
        return self.t_start + self.duration

    def describe(self) -> dict:
        out = {"kind": self.kind, "t_start": self.t_start}
        if self.duration != float("inf"):
            out["duration"] = self.duration
        if self.kind == "link_degrade":
            out.update(tier=self.tier, beta_scale=self.beta_scale,
                       alpha_add=self.alpha_add)
        elif self.kind in ("straggler", "node_kill"):
            out["node"] = self.node
            if self.kind == "straggler":
                out["compute_scale"] = self.compute_scale
        else:
            out["n_drops"] = self.n_drops
        return out


class FaultInjector:
    """Arms ``FaultSpec``s on an engine/cluster pair and logs every action.

    ``log`` records ``(time, action, spec)`` tuples in firing order --
    ``action`` is ``"apply"`` or ``"revert"`` -- so tests can assert that
    the same seed yields the identical schedule.  Observers registered via
    ``on_fault`` are called as ``fn(action, spec)`` right after the cluster
    state changed; the serving layer uses this to notice node kills.
    """

    def __init__(self, engine: Engine, cluster: SimCluster,
                 specs=()) -> None:
        self.engine = engine
        self.cluster = cluster
        self.specs = list(specs)
        self.log: list[tuple[float, str, FaultSpec]] = []
        self._observers: list = []
        self._active_links: list[FaultSpec] = []
        self._armed = False

    def on_fault(self, fn) -> None:
        self._observers.append(fn)

    def arm(self) -> None:
        """Schedule every spec's apply (and finite revert) on the engine.

        Fault events carry priority -1 so a fault taking effect at time t
        is visible to every ordinary event at the same instant.
        """
        if self._armed:
            raise RuntimeError("FaultInjector.arm() called twice")
        self._armed = True
        for spec in sorted(self.specs, key=lambda s: (s.t_start, s.kind)):
            self.engine.at(spec.t_start, self._apply, spec, priority=-1)
            if spec.duration != float("inf"):
                self.engine.at(spec.t_end, self._revert, spec, priority=-1)

    # -- state transitions ----------------------------------------------

    def _effective_topology(self):
        """Healthy baseline + every currently-active link fault."""
        topo = self.cluster.healthy_topo
        for spec in self._active_links:
            topo = topo.degraded(
                spec.tier, beta_scale=spec.beta_scale,
                alpha_add=spec.alpha_add,
            )
        return topo

    def _apply(self, spec: FaultSpec) -> None:
        cluster = self.cluster
        if spec.kind == "link_degrade":
            self._active_links.append(spec)
            cluster.set_topology(self._effective_topology())
        elif spec.kind == "straggler":
            cluster.set_compute_scale(spec.node, spec.compute_scale)
        elif spec.kind == "transient_drop":
            cluster.add_drops(spec.n_drops, until=spec.t_end)
        elif spec.kind == "node_kill":
            cluster.kill_node(spec.node)
        self._record("apply", spec)

    def _revert(self, spec: FaultSpec) -> None:
        cluster = self.cluster
        if spec.kind == "link_degrade":
            self._active_links.remove(spec)
            cluster.set_topology(self._effective_topology())
        elif spec.kind == "straggler":
            cluster.set_compute_scale(spec.node, 1.0)
        elif spec.kind == "transient_drop":
            pass  # expiry is enforced by the drop window itself
        elif spec.kind == "node_kill":
            cluster.restore_node(spec.node)
        self._record("revert", spec)

    def refresh(self) -> None:
        """Re-compose the active link faults onto the cluster's (possibly
        rebased) healthy topology -- the recovery path calls this after
        ``shrink_to`` so a brownout outlives a node loss."""
        if self._active_links:
            self.cluster.set_topology(self._effective_topology())

    def _record(self, action: str, spec: FaultSpec) -> None:
        self.log.append((self.engine.now, action, spec))
        for fn in self._observers:
            fn(action, spec)

    def schedule(self) -> list[dict]:
        """The armed schedule as plain dicts (for artifacts and tests)."""
        rows = []
        for spec in sorted(self.specs, key=lambda s: (s.t_start, s.kind)):
            rows.append(spec.describe())
        return rows


def random_faults(
    seed: int,
    horizon: float,
    *,
    n_faults: int = 3,
    kinds=("link_degrade", "straggler", "transient_drop"),
    n_nodes: int = 1,
    n_tiers: int = 2,
    mean_duration: float | None = None,
) -> list[FaultSpec]:
    """A deterministic random fault schedule: same seed, same list.

    Start times are uniform over the first 80% of the horizon, durations
    exponential with mean ``mean_duration`` (default ``horizon / 10``),
    severities drawn from modest ranges (2-8x bandwidth cut, 1.5-4x
    straggle).  ``node_kill`` is excluded by default -- recovery scenarios
    compose it explicitly rather than by lottery.
    """
    if n_faults < 0:
        raise ValueError(f"n_faults must be >= 0, got {n_faults}")
    rng = random.Random(seed)
    mean_dur = horizon / 10.0 if mean_duration is None else mean_duration
    specs = []
    for _ in range(n_faults):
        kind = rng.choice(list(kinds))
        t_start = rng.uniform(0.0, 0.8 * horizon)
        duration = min(rng.expovariate(1.0 / mean_dur), horizon - t_start)
        if kind == "link_degrade":
            specs.append(FaultSpec(
                kind, t_start, duration,
                tier=rng.randrange(n_tiers),
                beta_scale=rng.uniform(2.0, 8.0),
                alpha_add=rng.uniform(0.0, 100e-6),
            ))
        elif kind == "straggler":
            specs.append(FaultSpec(
                kind, t_start, duration,
                node=rng.randrange(n_nodes),
                compute_scale=rng.uniform(1.5, 4.0),
            ))
        elif kind == "transient_drop":
            specs.append(FaultSpec(
                kind, t_start, duration, n_drops=rng.randint(1, 3),
            ))
        elif kind == "node_kill":
            specs.append(FaultSpec(kind, t_start, duration,
                                   node=rng.randrange(n_nodes)))
        else:
            raise ValueError(f"unknown fault kind {kind!r}")
    return sorted(specs, key=lambda s: (s.t_start, s.kind))


def scale_faults(specs, t_scale: float) -> list[FaultSpec]:
    """Shift a fault schedule onto a stretched/compressed horizon."""
    return [
        replace(s, t_start=s.t_start * t_scale,
                duration=(s.duration * t_scale
                          if s.duration != float("inf") else s.duration))
        for s in specs
    ]
