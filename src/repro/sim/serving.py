"""Prefill/decode request lifecycles with continuous batching.

``ServingSim`` replays a workload trace (``sim.workload``) against a
``SimCluster``: one serving instance spans the whole topology
(tensor/model parallel), requests queue FIFO, and a step loop performs
continuous batching -- at every step boundary new requests are admitted
while the batch has room AND their (sharded) KV-cache footprint is
reservable on every node.

Step cost model (all terms calibrated or calibratable):

    t_step = step_overhead
           + prefill_time_per_token * (prompt tokens entering this step)
           + decode_time_per_token  * (sequences decoding this step)
           + collective_time(all_reduce, tp_sync_bytes_per_token * tokens)

The collective term is the model's whole point: every step ends in a
tensor-parallel sync whose payload scales with the tokens processed, and
its duration comes from the EXACT round model on the calibrated topology
(``SimCluster.collective_time``), so queueing and tail latency inherit the
paper's cost structure rather than an ad-hoc constant.  Payload sizes are
quantized (``sync_quantum_bytes``) to keep the set of distinct schedules
small -- memoization makes a million-step run cheap.

A request's first step is its prefill (TTFT = end of that step); each
subsequent step yields one token.  Per-request records keep every step
latency, so the simulator emits the same p50/p99 metric schema the live
``serve.Engine`` reports.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field

from ..comm.health import RetryPolicy, StepWatchdog
from .cluster import SimCluster
from .workload import Request, Trace


def percentile(values, q: float) -> float:
    """Deterministic nearest-rank percentile (q in [0, 100])."""
    if not values:
        return float("nan")
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    k = (q / 100.0) * (len(xs) - 1)
    lo = int(k)
    hi = min(lo + 1, len(xs) - 1)
    return float(xs[lo] + (xs[hi] - xs[lo]) * (k - lo))


@dataclass(frozen=True)
class ServingConfig:
    """Cost/capacity parameters of one serving instance."""

    max_batch: int = 8
    kv_bytes_per_token: float = 4096.0     # per-sequence KV, before sharding
    kv_capacity_bytes: float = float("inf")  # per node
    prefill_time_per_token: float = 20e-6
    decode_time_per_token: float = 2e-3    # per sequence per step
    step_overhead: float = 1e-3
    tp_sync_bytes_per_token: float = 8192.0
    collective: str = "all_reduce"
    strategy: str | None = None            # None => planner's best_plan
    sync_quantum_bytes: float = 16384.0    # payload quantization grid
    # fault handling (see ``attach_faults``)
    restore_overhead_s: float = 0.5        # checkpoint-restore constant
    restore_bytes: float = 64e6            # state re-materialized on recovery
    max_queue_wait_s: float = float("inf")  # shed queued requests past this

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.sync_quantum_bytes <= 0:
            raise ValueError("sync_quantum_bytes must be positive")
        if self.restore_overhead_s < 0 or self.restore_bytes < 0:
            raise ValueError("restore costs must be >= 0")
        if self.max_queue_wait_s <= 0:
            raise ValueError("max_queue_wait_s must be positive")


@dataclass
class RequestRecord:
    """Lifecycle timestamps + per-step latencies of one request."""

    req: Request
    t_admitted: float = float("nan")
    t_first_token: float = float("nan")
    t_finish: float = float("nan")
    tokens_done: int = 0
    step_latencies: list = field(default_factory=list)
    shed: bool = False        # dropped by admission control, never served
    n_restarts: int = 0       # times restarted by an elastic recovery

    @property
    def latency(self) -> float:
        return self.t_finish - self.req.t_arrival

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.req.t_arrival

    @property
    def queue_wait(self) -> float:
        return self.t_admitted - self.req.t_arrival


class ServingSim:
    """Continuous-batching serving loop over a SimCluster."""

    def __init__(self, cluster: SimCluster, cfg: ServingConfig) -> None:
        self.cluster = cluster
        self.cfg = cfg
        self.queue: deque = deque()
        self.active: list[RequestRecord] = []
        self.records: list[RequestRecord] = []
        self.step_durations: list[float] = []
        self._step_running = False
        self._prefilling: list[RequestRecord] = []
        # time-averaged number-in-system for the Little's-law check
        self._n_in_system = 0
        self._area = 0.0
        self._last_change = 0.0
        self._busy_area = 0.0
        # per-node KV footprint of one cached token (sharded across procs)
        self._kv_per_node_token = (
            cfg.kv_bytes_per_token / cluster.topo.n_procs
        )
        for node in cluster.nodes:
            node.kv_capacity_bytes = cfg.kv_capacity_bytes
        # fault state -- inert until ``attach_faults`` is called
        self.injector = None
        self.retry = RetryPolicy()
        self.watchdog = StepWatchdog(expected_s=self._expected_step_s())
        self._halted = False          # node lost: detection/recovery pending
        self._step_event = None       # cancellable handle of the step's end
        self._t_kill = float("nan")
        self._last_sync_bytes = cfg.sync_quantum_bytes
        self.n_shed = 0
        self.n_retries = 0
        self.n_slow_steps = 0
        self.recoveries: list[dict] = []

    # -- faults ----------------------------------------------------------

    def _expected_step_s(self) -> float:
        """Modelled healthy single-decode step: the watchdog's seed."""
        return (
            self.cfg.step_overhead
            + self.cfg.decode_time_per_token
            + self.cluster.collective_time(
                self.cfg.collective, self.cfg.sync_quantum_bytes,
                strategy=self.cfg.strategy,
            )
        )

    def attach_faults(self, injector, retry: RetryPolicy | None = None) -> None:
        """Subscribe to a ``FaultInjector``'s events (before ``run``).

        Link degradations and stragglers need no subscription -- their
        price shows up in the next step automatically -- but node kills
        drive the detection/recovery state machine here.
        """
        self.injector = injector
        if retry is not None:
            self.retry = retry
        injector.on_fault(self._on_fault)

    def _on_fault(self, action: str, spec) -> None:
        if spec.kind == "node_kill" and action == "apply":
            self._begin_node_loss()

    def _begin_node_loss(self) -> None:
        """A node just died.  The in-flight step hangs; the watchdog's
        timeout is the detection latency before recovery starts."""
        if self._halted:
            return  # already detecting/recovering; fold into this episode
        self._halted = True
        self._t_kill = self.cluster.engine.now
        if self._step_event is not None:
            self._step_event.cancel()
            self._step_event = None
        self.cluster.engine.schedule(
            self.watchdog.timeout_s, self._on_node_loss_detected
        )

    def _on_node_loss_detected(self) -> None:
        """Shrink to survivors, re-plan, pay the restore, then resume."""
        cluster = self.cluster
        t_detected = cluster.engine.now
        self._account(0)
        self._step_running = False
        # in-flight requests lost their KV shards on the dead node: they
        # restart from prefill, ahead of everything queued behind them
        restarted = list(self.active)
        for rec in restarted:
            self._release_kv(rec.req)
            rec.tokens_done = 0
            rec.n_restarts += 1
            rec.t_admitted = float("nan")
        self.queue.extendleft(reversed(restarted))
        self.active = []
        self._prefilling = []
        plan_before = cluster.plan_for(
            self.cfg.collective, self._last_sync_bytes
        )
        new_topo = cluster.healthy_topo.shrunk(sorted(cluster.dead_nodes))
        cluster.shrink_to(new_topo)
        if self.injector is not None:
            self.injector.refresh()  # re-compose active link faults
        self._kv_per_node_token = (
            self.cfg.kv_bytes_per_token / new_topo.n_procs
        )
        for node in cluster.nodes:
            node.kv_capacity_bytes = self.cfg.kv_capacity_bytes
        plan_after = cluster.plan_for(
            self.cfg.collective, self._last_sync_bytes
        )
        t_reshard = cluster.collective_time(
            self.cfg.collective, self.cfg.restore_bytes,
            strategy=self.cfg.strategy,
        )
        self.recoveries.append({
            "t_kill_s": self._t_kill,
            "t_detected_s": t_detected,
            "detect_latency_s": t_detected - self._t_kill,
            "restore_s": self.cfg.restore_overhead_s + t_reshard,
            "n_restarted": len(restarted),
            "n_procs_after": new_topo.n_procs,
            "plan_before": plan_before,
            "plan_after": plan_after,
        })
        cluster.engine.schedule(
            self.cfg.restore_overhead_s + t_reshard, self._finish_recovery
        )

    def _finish_recovery(self) -> None:
        now = self.cluster.engine.now
        rec = self.recoveries[-1]
        rec["t_resumed_s"] = now
        rec["recovery_time_s"] = now - rec["t_kill_s"]
        self._halted = False
        self._t_kill = float("nan")
        self.watchdog.rebase(self._expected_step_s())
        if self.active or self.queue:
            self._start_step()

    def _should_shed(self, rec: RequestRecord) -> bool:
        """Admission shedding: a request that can NEVER fit the shrunk KV
        budget, or has waited past the queue-wait ceiling, is dropped
        rather than left blocking the head of the queue forever."""
        per_node = self._kv_footprint(rec.req)
        if per_node > min(
            n.kv_capacity_bytes for n in self.cluster.nodes
        ):
            return True
        wait = self.cluster.engine.now - rec.req.t_arrival
        return wait > self.cfg.max_queue_wait_s

    def _shed(self, rec: RequestRecord) -> None:
        rec.shed = True
        self.n_shed += 1
        self._account(-1)

    # -- bookkeeping ----------------------------------------------------

    def _account(self, delta: int) -> None:
        now = self.cluster.engine.now
        self._area += self._n_in_system * (now - self._last_change)
        self._busy_area += (
            (now - self._last_change) if self._step_running else 0.0
        )
        self._last_change = now
        self._n_in_system += delta

    def _kv_footprint(self, req: Request) -> float:
        return self._kv_per_node_token * req.total_tokens

    def _reserve_kv(self, req: Request) -> bool:
        per_node = self._kv_footprint(req)
        if not all(n.can_reserve(per_node) for n in self.cluster.nodes):
            return False
        for n in self.cluster.nodes:
            n.reserve(per_node)
        return True

    def _release_kv(self, req: Request) -> None:
        per_node = self._kv_footprint(req)
        for n in self.cluster.nodes:
            n.release(per_node)

    # -- lifecycle ------------------------------------------------------

    def start(self, trace: Trace) -> None:
        """Schedule every arrival on the engine (call before ``run``)."""
        for req in trace.requests:
            self.cluster.engine.at(req.t_arrival, self._on_arrival, req)

    def _on_arrival(self, req: Request) -> None:
        rec = RequestRecord(req)
        self.records.append(rec)
        self.queue.append(rec)
        self._account(+1)
        if not self._step_running and not self._halted:
            self._start_step()

    def _start_step(self) -> None:
        if self._halted:
            return  # a node is lost; nothing runs until recovery finishes
        # continuous batching: top the batch up at every step boundary
        admitted = []
        while self.queue and len(self.active) < self.cfg.max_batch:
            rec = self.queue[0]
            if self._should_shed(rec):
                self.queue.popleft()
                self._shed(rec)
                continue
            if not self._reserve_kv(rec.req):
                break  # head-of-line blocks until KV frees (FIFO fairness)
            self.queue.popleft()
            rec.t_admitted = self.cluster.engine.now
            self.active.append(rec)
            admitted.append(rec)
        if not self.active:
            return  # nothing runnable (queue empty or KV-blocked & idle)
        self._account(0)  # flush integrals while still marked idle
        self._step_running = True
        self._prefilling = admitted
        prompt_tokens = sum(r.req.prompt_tokens for r in admitted)
        n_decoding = len(self.active) - len(admitted)
        n_tokens = prompt_tokens + n_decoding
        compute = (
            self.cfg.step_overhead
            + self.cfg.prefill_time_per_token * prompt_tokens
            + self.cfg.decode_time_per_token * n_decoding
        ) * self.cluster.compute_multiplier()  # stragglers pace the step
        q = self.cfg.sync_quantum_bytes
        sync_bytes = max(
            q, q * round(self.cfg.tp_sync_bytes_per_token * n_tokens / q)
        )
        self._last_sync_bytes = sync_bytes
        t_sync = self.cluster.collective_time(
            self.cfg.collective, sync_bytes, strategy=self.cfg.strategy
        )
        # transient drops: each failed collective is retried after a
        # bounded backoff, re-paying the sync (health-layer pricing)
        n_retries = 0
        while (n_retries < self.retry.max_attempts - 1
               and self.cluster.consume_drop()):
            n_retries += 1
        self.n_retries += n_retries
        t_step = (compute + t_sync
                  + n_retries * t_sync + self.retry.total_delay(n_retries))
        self.step_durations.append(t_step)
        self.cluster.n_collectives += 1
        self._step_event = self.cluster.engine.schedule(
            t_step, self._end_step, t_step
        )

    def _end_step(self, t_step: float) -> None:
        now = self.cluster.engine.now
        self._step_event = None
        if self.watchdog.observe(t_step) == "slow":
            self.n_slow_steps += 1
        self._account(0)  # flush the step's busy time before going idle
        self._step_running = False
        still_active = []
        for rec in self.active:
            rec.step_latencies.append(t_step)
            rec.tokens_done += 1  # prefill emits the first token
            if rec.t_first_token != rec.t_first_token:  # still NaN
                rec.t_first_token = now
            if rec.tokens_done >= rec.req.gen_tokens:
                rec.t_finish = now
                self._release_kv(rec.req)
                self._account(-1)
            else:
                still_active.append(rec)
        self.active = still_active
        self._prefilling = []
        if self.active or self.queue:
            self._start_step()

    def run(self, trace: Trace, max_events: int | None = None) -> dict:
        """Replay ``trace`` to completion and return summary metrics."""
        self.start(trace)
        self.cluster.engine.run(max_events=max_events)
        self._account(0)  # close the number-in-system integral
        return self.summarize(trace)

    # -- metrics --------------------------------------------------------

    def summarize(self, trace: Trace) -> dict:
        done = [r for r in self.records if r.t_finish == r.t_finish]
        span = max(self._last_change, trace.cfg.horizon)
        latencies = [r.latency for r in done]
        ttfts = [r.ttft for r in done]
        steps = [s for r in done for s in r.step_latencies]
        tokens_out = sum(r.tokens_done for r in done)
        return {
            "n_requests": len(self.records),
            "n_completed": len(done),
            "span_s": span,
            "offered_rps": trace.offered_rate,
            "throughput_rps": len(done) / span if span else 0.0,
            "throughput_tok_s": tokens_out / span if span else 0.0,
            "latency_p50_s": percentile(latencies, 50),
            "latency_p99_s": percentile(latencies, 99),
            "latency_mean_s": (
                sum(latencies) / len(latencies) if latencies else float("nan")
            ),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "step_p50_s": percentile(steps, 50),
            "step_p99_s": percentile(steps, 99),
            "mean_in_system": self._area / span if span else 0.0,
            "utilization": self._busy_area / span if span else 0.0,
            "n_steps": len(self.step_durations),
            "n_events": self.cluster.engine.n_processed,
            # fault/recovery metrics (all zero on a healthy run)
            "n_shed": self.n_shed,
            "n_retries": self.n_retries,
            "n_slow_steps": self.n_slow_steps,
            "n_recoveries": len(self.recoveries),
            "n_restarted": sum(r["n_restarted"] for r in self.recoveries),
            "recovery_time_s": sum(
                r.get("recovery_time_s", 0.0) for r in self.recoveries
            ),
            "recoveries": list(self.recoveries),
        }
