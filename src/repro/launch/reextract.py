"""Re-parse saved .hlo.gz files and update the dry-run JSONs in place
(parser iterations without recompiling)."""

import argparse
import gzip
import json
from pathlib import Path

from repro.launch import hlo_stats
from repro.launch.mesh import POD_CHIPS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    for hz in sorted(Path(args.out).glob("*.hlo.gz")):
        jf = hz.with_suffix("").with_suffix(".json")
        if not jf.exists():
            continue
        rec = json.loads(jf.read_text())
        n_dev = rec.get("n_devices", 256)
        hlo = gzip.decompress(hz.read_bytes()).decode()
        st = hlo_stats.parse_collectives(hlo, n_dev, POD_CHIPS)
        rec["collectives"] = {
            "by_kind": st.by_kind(),
            "wire_bytes_per_device": st.total_wire_bytes_per_device(),
            "wire_bytes_bf16_corrected": st.total_wire_bf16_corrected(),
            "pod_crossing_bytes_total": st.total_crossing_bytes(),
            "n_ops": len(st.ops),
        }
        rec["parser"] = "loop-aware-v2"
        jf.write_text(json.dumps(rec, indent=1))
        print(f"{jf.name}: wire/dev={st.total_wire_bytes_per_device()/1e9:.2f}GB "
              f"crossing={st.total_crossing_bytes()/1e9:.2f}GB ops={len(st.ops)}")


if __name__ == "__main__":
    main()
