"""Production mesh construction.

Single pod:  (16, 16)    axes ("data", "model")        -- 256 chips.
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") -- 512 chips.

Device order is row-major, so chip id = pod*256 + data*16 + model; the
roofline tier classifier (benchmarks/hlo_collectives.py) relies on this to
decide which replica groups cross the pod seam (the paper's global edges).

``make_production_mesh`` is a function (never a module constant): importing
this module must not touch jax device state.
"""

from __future__ import annotations

import jax

POD_CHIPS = 256
N_PODS = 2


def make_production_mesh(*, multi_pod: bool = False):
    shape = (N_PODS, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for multi-device subprocess tests (8 fake devices)."""
    return jax.make_mesh(shape, axes)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
