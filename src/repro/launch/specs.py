"""Abstract input construction for every (arch x shape x mesh) cell.

Everything here is ShapeDtypeStruct-based: the dry-run lowers and compiles
WITHOUT allocating a single model byte (314B-parameter configs compile on a
laptop-sized host).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import rules
from repro.train import steps as train_steps


# Per-arch training memory knobs (microbatching + remat), chosen so every
# train cell's per-device peak fits v5e HBM (16 GB); values recorded in
# EXPERIMENTS.md SDry-run.
TRAIN_OVERRIDES: dict[str, dict] = {
    "grok-1-314b": dict(accum_steps=32, remat="nothing", moments="bfloat16",
                        accum_dtype="bfloat16"),
    "qwen2-vl-72b": dict(accum_steps=16, remat="nothing", moments="bfloat16",
                         accum_dtype="bfloat16"),
    "command-r-35b": dict(accum_steps=8, remat="nothing", moments="bfloat16"),
    "granite-3-8b": dict(accum_steps=4, remat="nothing", moments="bfloat16"),
    "qwen2-moe-a2.7b": dict(accum_steps=4, remat="nothing"),
    "llama3.2-3b": dict(accum_steps=2, remat="nothing"),
    "llama3.2-1b": dict(accum_steps=2, remat="nothing"),
    "zamba2-2.7b": dict(accum_steps=4, remat="nothing"),
    "rwkv6-1.6b": dict(accum_steps=2, remat="nothing"),
    "seamless-m4t-medium": dict(accum_steps=8, remat="nothing"),
}

# long_500k runs with a bounded attention window on the hybrid arch.
LONG_CTX_WINDOW = 4096


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def _vocab_divisible(cfg: ModelConfig, mesh) -> bool:
    tp = mesh.devices.shape[-1]
    return cfg.padded_vocab % tp == 0


def make_policy_for(cfg: ModelConfig, mesh,
                    variant: str = "default") -> rules.ShardingPolicy:
    fold = variant == "dp256"
    return rules.ShardingPolicy(
        shard_vocab=_vocab_divisible(cfg, mesh) and not fold,
        fold_model=fold,
    )


def abstract_params(cfg: ModelConfig, dtype=None):
    tree = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    if dtype is not None:
        tree = jax.tree.map(lambda s: sds(s.shape, dtype), tree)
    return tree


def effective_cfg(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    if shape.name == "long_500k" and cfg.family == "hybrid":
        return cfg.with_(sliding_window=LONG_CTX_WINDOW)
    return cfg


@dataclass
class Cell:
    """Everything needed to lower one (arch x shape x mesh) combination."""
    name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    meta: dict


def _batch_sds(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.int32) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": sds((B, S), jnp.int32),
        "labels": sds((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        # frontend stub: patch/token embeddings + M-RoPE grids
        batch["embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
        batch["positions"] = sds((3, B, S), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = sds((B, S, cfg.d_model), jnp.bfloat16)
    return batch


def _pod_axes(mesh) -> str | None:
    return "pod" if "pod" in mesh.axis_names else None


def train_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, pod_mode=None,
               pod_sync="flat", accum=None, remat=None,
               policy="default", calibration="", topology="v5e",
               overlap="off", compute_time=0.0) -> Cell:
    """Build one train cell.

    ``pod_sync`` may be any of ``comm.POD_SYNC_FORMATS`` ('flat', 'q8',
    'rs', 'rs_q8') or 'auto' -- 'auto' defers the DCN wire format AND the
    bucket size to ``repro.comm``'s pipelined cost model (planned per this
    model's gradient bytes; opts into the lossy q8 paths when compression
    wins).  ``calibration`` optionally names a ``comm.calibrate`` JSON so
    that the decision uses parameters fitted on this hardware instead of
    presets; ``topology`` picks the preset hierarchy the planner models
    ('v5e' two-tier, 'v5e_3tier' = ICI / host-PCIe / DCN).  ``overlap``
    ('off' | 'auto' | int) opts the cell into compute/comm overlap: the
    overlap-aware cost model weighs interleaving per-microbatch syncs with
    backward, sized by ``compute_time`` seconds of step compute (0 =
    roofline estimate from the cell's token count).  The resolved format,
    bucket size and overlap depth are recorded in ``meta['pod_sync']`` /
    ``meta['bucket_bytes']`` / ``meta['overlap']``.
    """
    cfg = effective_cfg(cfg, shape)
    pol = make_policy_for(cfg, mesh, variant=policy)
    pod_axis = _pod_axes(mesh)
    if pod_mode is None:
        pod_mode = "manual" if pod_axis else "none"
    over = TRAIN_OVERRIDES.get(cfg.name, {})
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    overlap = train_steps.parse_overlap(overlap)
    if overlap != "off" and compute_time <= 0:
        compute_time = train_steps.estimate_compute_time(
            cfg, shape.global_batch * shape.seq_len / max(n_pods, 1),
            chips_per_pod=mesh.devices.size // max(n_pods, 1),
        )
    tcfg = train_steps.TrainConfig(
        accum_steps=accum if accum is not None else over.get("accum_steps", 1),
        remat=remat if remat is not None else over.get("remat", "nothing"),
        pod_mode=pod_mode,
        pod_sync=pod_sync,
        calibration=calibration,
        topology=topology,
        overlap=overlap,
        compute_time=compute_time,
        use_kernel=False,          # CPU dry-run lowers the jnp paths
        accum_dtype=over.get("accum_dtype", "float32"),
        model_in_batch=pol.fold_model,
    )
    # Resolve 'auto' once, here: the step is built from the concrete format
    # + bucket size + overlap depth and meta records exactly what the
    # compiled step runs.
    decision = train_steps.plan_pod_sync(
        cfg, tcfg, n_pods, chips_per_pod=mesh.devices.size // max(n_pods, 1)
    )
    pod_sync = decision.fmt
    tcfg = dataclasses.replace(
        tcfg, pod_sync=pod_sync, bucket_bytes=decision.bucket_bytes,
        overlap=decision.overlap,
    )
    ocfg = adamw.AdamWConfig(moment_dtype=over.get("moments", "float32"))
    step, bspecs = train_steps.make_train_step(cfg, tcfg, ocfg, mesh, pol)

    params = abstract_params(cfg)
    pspecs = rules.param_specs(cfg, params, pol)
    opt = jax.eval_shape(
        functools.partial(adamw.init_state, moment_dtype=ocfg.moment_dtype),
        params,
    )
    ospecs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
    batch = _batch_sds(cfg, shape)

    n = lambda s: jax.tree.map(lambda sp: NamedSharding(mesh, sp), s,
                               is_leaf=lambda x: isinstance(x, P))
    in_sh = (n(pspecs), n(ospecs), n(bspecs))
    meta = dict(kind="train", accum=tcfg.accum_steps, remat=tcfg.remat,
                pod_mode=pod_mode, pod_sync=pod_sync,
                bucket_bytes=tcfg.bucket_bytes, policy=policy,
                topology=topology, overlap=decision.overlap,
                compute_time=compute_time)
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=step,
        args=(params, opt, batch),
        in_shardings=in_sh,
        out_shardings=(n(pspecs), n(ospecs), None),
        meta=dict(meta, donate=(0, 1)),
    )


def _dp_entry(mesh, B: int):
    """Batch-dim spec entry: joint (pod, data) when divisible, else data,
    else unsharded (B=1 long-context)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    axes = [a for a in ("pod", "data") if a in sizes]
    total = 1
    for a in axes:
        total *= sizes[a]
    if B > 1 and B % total == 0:
        return tuple(axes) if len(axes) > 1 else axes[0]
    if B > 1 and B % sizes.get("data", 1) == 0:
        return "data"
    return None


def _cache_specs(cfg: ModelConfig, pol: rules.ShardingPolicy, mesh, batch: int):
    """Decode-cache PartitionSpecs (see sharding.rules.cache_specs docs)."""
    tp_size = mesh.devices.shape[-1]
    dp = _dp_entry(mesh, batch)
    tp = pol.model_axis

    def kv(n_kv: int):
        if n_kv % tp_size == 0:
            return P(None, dp, None, tp, None)     # heads sharded
        return P(None, dp, tp, None, None)         # sequence sharded

    specs = {"pos": P()}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        specs["k"] = kv(cfg.n_kv_heads)
        specs["v"] = kv(cfg.n_kv_heads)
        if cfg.family == "encdec":
            specs["xk"] = kv(cfg.n_kv_heads)
            specs["xv"] = kv(cfg.n_kv_heads)
    elif cfg.family == "hybrid":
        specs["k"] = kv(cfg.n_kv_heads)
        specs["v"] = kv(cfg.n_kv_heads)
        specs["conv"] = P(None, dp, None, tp)
        specs["ssm"] = P(None, dp, tp, None, None)
    elif cfg.family == "ssm":
        specs["tm_shift"] = P(None, dp, tp)
        specs["tm_state"] = P(None, dp, tp, None, None)
        specs["cm_shift"] = P(None, dp, tp)
    return specs


def _dp_size(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("data", 1)


def _decode_policy(cfg: ModelConfig, mesh) -> rules.ShardingPolicy:
    """Weights-stationary serving: at one token per step, FSDP weight
    gathers dominate the collective term (they re-gather the whole model
    every step), so decode replicates params over 'data' (model-axis TP
    only) whenever bf16 params / 16 fit alongside the KV cache; only
    grok-1 (39 GB/chip at TP-16) keeps FSDP sharding."""
    tp = mesh.devices.shape[-1]
    bf16_per_chip = cfg.param_count() * 2 / tp
    fsdp = bf16_per_chip > 8e9
    return rules.ShardingPolicy(
        shard_vocab=_vocab_divisible(cfg, mesh), fsdp=fsdp
    )


def decode_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                weights_stationary: bool = True) -> Cell:
    cfg = effective_cfg(cfg, shape)
    pol = (_decode_policy(cfg, mesh) if weights_stationary
           else make_policy_for(cfg, mesh))
    B, S = shape.global_batch, shape.seq_len
    params = abstract_params(cfg, dtype=jnp.bfloat16)   # serving weights bf16
    pspecs = rules.param_specs(cfg, params, pol)
    cache = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, B, S, enc_len=min(S, 4096))
    )
    cspecs = _cache_specs(cfg, pol, mesh, B)
    # match spec tree to cache tree
    cspecs = {k: cspecs[k] for k in cache}
    dp = _dp_entry(mesh, B)
    tokens = sds((B,), jnp.int32)

    def serve_step(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache, batch_axes=dp)

    n = lambda s: jax.tree.map(lambda sp: NamedSharding(mesh, sp), s,
                               is_leaf=lambda x: isinstance(x, P))
    in_sh = (n(pspecs), n(cspecs), NamedSharding(mesh, P(dp)))
    out_sh = (NamedSharding(mesh, P(dp, None)), n(cspecs))
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=serve_step,
        args=(params, cache, tokens),
        in_shardings=in_sh,
        out_shardings=out_sh,
        meta=dict(kind="decode", window=cfg.sliding_window, donate=(1,),
                  weights_stationary=pol.fsdp is False or not pol.fsdp),
    )


def prefill_cell(cfg: ModelConfig, shape: ShapeSpec, mesh) -> Cell:
    cfg = effective_cfg(cfg, shape)
    pol = make_policy_for(cfg, mesh)
    B, S = shape.global_batch, shape.seq_len
    params = abstract_params(cfg, dtype=jnp.bfloat16)
    pspecs = rules.param_specs(cfg, params, pol)
    cache = jax.eval_shape(
        functools.partial(lm.init_cache, cfg, B, S, enc_len=S)
    )
    cspecs = _cache_specs(cfg, pol, mesh, B)
    cspecs = {k: cspecs[k] for k in cache}
    dp = _dp_entry(mesh, B)
    tokens = sds((B, S), jnp.int32)
    enc = sds((B, S, cfg.d_model), jnp.bfloat16) if cfg.family == "encdec" else None

    def prefill_step(params, cache, tokens, enc_embeds=None):
        return lm.prefill(
            params, cfg, tokens, cache, enc_embeds=enc_embeds,
            use_kernel=False, batch_axes=dp,
        )

    n = lambda s: jax.tree.map(lambda sp: NamedSharding(mesh, sp), s,
                               is_leaf=lambda x: isinstance(x, P))
    args = (params, cache, tokens) + ((enc,) if enc is not None else ())
    in_sh = (n(pspecs), n(cspecs), NamedSharding(mesh, P(dp, None))) + (
        (NamedSharding(mesh, P(dp, None, None)),) if enc is not None else ()
    )
    out_sh = (NamedSharding(mesh, P(dp, None)), n(cspecs))
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=prefill_step,
        args=args,
        in_shardings=in_sh,
        out_shardings=out_sh,
        meta=dict(kind="prefill", donate=(1,)),
    )


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw) -> Cell:
    if shape.kind == "train":
        return train_cell(cfg, shape, mesh, **kw)
    if shape.kind == "decode":
        return decode_cell(cfg, shape, mesh)
    if shape.kind == "prefill":
        return prefill_cell(cfg, shape, mesh)
    raise ValueError(shape.kind)
