"""Serving launcher: batched prefill + decode with the sharded KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.models import lm
from repro.models.config import reduced_for_smoke


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    cfg = cfg.with_(compute_dtype="float32")

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc = (
        jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
        if cfg.family == "encdec" else None
    )

    from repro.serve.engine import Engine

    eng = Engine(cfg, params, max_len=S + args.gen,
                 temperature=args.temperature, seed=args.seed)
    res = eng.generate(prompts, args.gen, enc_embeds=enc)
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"[serve] prefill: {res.prefill_s*1e3:.1f}ms "
          f"({B*S/res.prefill_s:,.0f} tok/s); decode: "
          f"{res.decode_s*1e3/max(args.gen-1,1):.1f}ms/step "
          f"({res.decode_tok_s:,.0f} tok/s)")
    print(f"[serve] sample tokens[0,:16]: {res.tokens[0,:16].tolist()}")


if __name__ == "__main__":
    main()
