"""Serving launcher: batched prefill + decode, live or simulated.

Live single-batch generation (the original entry point):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --batch 4 --prompt-len 64 --gen 32

Scenario mode drives ``repro.sim``'s named serving scenarios -- the same
experiment either through the discrete-event simulator (``--mode sim``,
optionally on fitted tiers via ``--calibration``) or replayed through the
real engine on this host (``--mode live``):

  PYTHONPATH=src python -m repro.launch.serve --scenario smoke --mode sim
  PYTHONPATH=src python -m repro.launch.serve --scenario smoke --mode sim \\
      --calibration calibration.json
  PYTHONPATH=src python -m repro.launch.serve --scenario smoke --mode live
"""

from __future__ import annotations

import argparse
import json


def _run_scenario(args) -> None:
    from repro.sim import get_scenario, run_scenario

    sc = get_scenario(args.scenario)
    metrics = run_scenario(
        sc, args.mode, calibration=args.calibration,
        rate_scale=args.rate_scale,
        live_timeout_s=args.live_timeout or None,
    )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
    print(f"[serve] scenario={sc.name} mode={args.mode} "
          f"({sc.doc or 'no description'})")
    for k in ("n_requests", "n_completed", "n_errors", "throughput_rps",
              "throughput_tok_s", "latency_p50_s", "latency_p99_s",
              "ttft_p50_s", "ttft_p99_s", "step_p50_s", "step_p99_s"):
        v = metrics.get(k)
        if v is None and k == "n_errors":
            continue
        if isinstance(v, float):
            print(f"[serve]   {k} = {v:.6g}")
        else:
            print(f"[serve]   {k} = {v}")
    for row in metrics.get("errors", []):
        print(f"[serve]   ERROR rid={row['rid']}: {row['error']}")


def _run_live_batch(args) -> None:
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.config import reduced_for_smoke

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    cfg = cfg.with_(compute_dtype="float32")

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    enc = (
        jax.random.normal(key, (B, S, cfg.d_model)) * 0.1
        if cfg.family == "encdec" else None
    )

    from repro.serve.engine import Engine

    eng = Engine(cfg, params, max_len=S + args.gen,
                 temperature=args.temperature, seed=args.seed)
    res = eng.generate(prompts, args.gen, enc_embeds=enc,
                       stop_tokens=tuple(args.stop_token))
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} gen={args.gen}"
          + (" (stopped early)" if res.stopped_early else ""))
    print(f"[serve] prefill: {res.prefill_s*1e3:.1f}ms "
          f"({B*S/res.prefill_s:,.0f} tok/s); decode: "
          f"{res.decode_s*1e3/max(res.steps-1,1):.1f}ms/step "
          f"({res.decode_tok_s:,.0f} tok/s)")
    if res.step_latencies_s:
        print(f"[serve] step latency p50 {res.step_p50_s*1e3:.1f}ms "
              f"p99 {res.step_p99_s*1e3:.1f}ms over {res.steps} steps")
    print(f"[serve] sample tokens[0,:16]: {res.tokens[0,:16].tolist()}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--stop-token", type=int, action="append", default=[],
                    help="token id ending a sequence (repeatable)")
    ap.add_argument("--scenario",
                    help="run a repro.sim serving scenario instead of a "
                         "single live batch")
    ap.add_argument("--mode", choices=("sim", "live"), default="sim",
                    help="scenario mode: discrete-event sim or live replay")
    ap.add_argument("--calibration",
                    help="calibration JSON for the sim's link tiers")
    ap.add_argument("--rate-scale", type=float, default=1.0,
                    help="multiply the scenario's offered load")
    ap.add_argument("--live-timeout", type=float, default=0.0,
                    help="per-request deadline in seconds for --mode live: "
                         "a generate call exceeding it is recorded as an "
                         "error row instead of wedging the replay "
                         "(0 = no deadline)")
    ap.add_argument("--out", help="write scenario metrics JSON here")
    args = ap.parse_args()

    if args.scenario:
        _run_scenario(args)
        return
    if not args.arch:
        ap.error("either --arch (live batch) or --scenario is required")
    _run_live_batch(args)


if __name__ == "__main__":
    main()
