"""Training launcher.

Production invocation targets the pod meshes (same code path the dry-run
proves out); on this CPU box it runs reduced configs end-to-end:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \\
      --steps 200 --global-batch 8 --seq 256 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.core.topology import TOPOLOGY_PRESETS
from repro.data.pipeline import DataConfig, make_pipeline
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import reduced_for_smoke
from repro.optim import adamw
from repro.sharding import rules
from repro.train import loop as train_loop
from repro.train import steps as train_steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--pod-sync", default="flat",
                    choices=["flat", "q8", "rs", "rs_q8", "auto"],
                    help="pod-tier wire format; 'rs'/'rs_q8' use the "
                         "bandwidth-optimal reduce-scatter exchange, "
                         "'auto' defers to the pipelined cost model "
                         "(calibrated when --calibration or "
                         "$REPRO_CALIBRATION names a fit)")
    ap.add_argument("--bucket-bytes", type=int, default=0,
                    help="pod-sync bucket size in bytes (0 = monolithic; "
                         "with --pod-sync auto the cost model chooses)")
    ap.add_argument("--calibration", default="",
                    help="comm.calibrate JSON fitted on this hardware; "
                         "consumed by --pod-sync auto")
    ap.add_argument("--overlap", default="off",
                    help="compute/comm overlap for the pod-tier sync "
                         "('off' | 'auto' | an int overlap depth): 'auto' "
                         "lets the overlap-aware cost model interleave "
                         "per-microbatch gradient syncs with backward "
                         "(needs --accum > 1)")
    ap.add_argument("--compute-time", type=float, default=0.0,
                    help="measured seconds of one step's forward+backward "
                         "compute, sizing the overlap planner's backward "
                         "shadow (0 = roofline estimate from the model "
                         "FLOPs and batch shape)")
    ap.add_argument("--topology", default="v5e",
                    choices=sorted(TOPOLOGY_PRESETS),
                    help="topology preset the pod-sync planner models the "
                         "cluster with ('v5e' = two-tier collapse, "
                         "'v5e_3tier' = ICI / host-PCIe / DCN hierarchy)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--kill-pod-at", type=int, default=-1,
                    help="inject a pod loss at this step and exercise the "
                         "elastic recovery path: restore the newest "
                         "checkpoint, re-mesh onto the surviving pods, "
                         "re-plan the pod sync on the shrunk topology, and "
                         "continue (needs --pods >= 2; --global-batch must "
                         "divide by pods-1)")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (requires 256 devices)")
    ap.add_argument("--pods", type=int, default=1,
                    help="explicit pod-axis extent; >1 enables the manual "
                         "pod-tier sync (pod_sync applies to the DCN seam)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M-param runs)")
    ap.add_argument("--layers", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_for_smoke(cfg)
    if args.d_model:
        cfg = cfg.with_(d_model=args.d_model, head_dim=args.d_model // cfg.n_heads)
    if args.layers:
        cfg = cfg.with_(n_layers=args.layers)
    cfg = cfg.with_(compute_dtype="float32")  # CPU numerics

    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.pods > 1)
        pod_extent = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
        if args.pods > 1 and args.pods != pod_extent:
            raise SystemExit(
                f"--pods {args.pods} conflicts with the production mesh's "
                f"fixed pod extent ({pod_extent})"
            )
    else:
        n = len(jax.devices())
        if args.pods > 1:
            if n % args.pods:
                raise SystemExit(f"--pods {args.pods} does not divide {n} devices")
            mesh = jax.make_mesh(
                (args.pods, n // args.pods, 1), ("pod", "data", "model")
            )
        else:
            mesh = jax.make_mesh((n, 1), ("data", "model"))

    pol = rules.ShardingPolicy(shard_vocab=cfg.vocab_size % mesh.devices.shape[-1] == 0)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    overlap = train_steps.parse_overlap(args.overlap)
    compute_time = args.compute_time
    if overlap != "off" and compute_time <= 0:
        compute_time = train_steps.estimate_compute_time(
            cfg, args.global_batch * args.seq / max(n_pods, 1),
            chips_per_pod=mesh.devices.size // max(n_pods, 1),
        )
    tcfg = train_steps.TrainConfig(
        accum_steps=args.accum, remat=args.remat, pod_sync=args.pod_sync,
        bucket_bytes=args.bucket_bytes,
        pod_mode="manual" if "pod" in mesh.axis_names else "none",
        use_kernel=False, calibration=args.calibration,
        topology=args.topology,
        overlap=overlap, compute_time=compute_time,
    )
    decision = train_steps.plan_pod_sync(
        cfg, tcfg, n_pods, chips_per_pod=mesh.devices.size // max(n_pods, 1)
    )
    tcfg = dataclasses.replace(
        tcfg, pod_sync=decision.fmt, bucket_bytes=decision.bucket_bytes,
        overlap=decision.overlap,
    )
    if n_pods > 1:
        print(f"[train] {decision.describe()} "
              f"(requested {args.pod_sync!r}, overlap={args.overlap!r}, "
              f"topology={args.topology}, "
              f"calibration={args.calibration or '$REPRO_CALIBRATION/preset'})")

    ocfg = adamw.AdamWConfig(
        lr=args.lr, total_steps=args.steps,
        warmup_steps=max(args.steps // 20, 5),
    )
    step_fn, bspecs = train_steps.make_train_step(cfg, tcfg, ocfg, mesh, pol)

    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.global_batch}x{args.seq} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")
    opt_state = adamw.init_state(params)

    data = make_pipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.global_batch, seed=args.seed,
    ))

    # the mesh and jitted step live in a mutable holder so the elastic
    # recovery path can swap both under the same stepper closure
    holder = {"mesh": mesh, "jitted": jax.jit(step_fn, donate_argnums=(0, 1))}

    def stepper(p, o, b):
        # Trace inside the mesh context so the pod-sync sharding
        # constraints (PartitionSpecs over 'pod') resolve instead of
        # falling back (see comm.grad_sync._pin).
        with holder["mesh"]:
            return holder["jitted"](p, o, b)

    recover = None
    if args.kill_pod_at >= 0:
        if n_pods < 2:
            raise SystemExit("--kill-pod-at needs --pods >= 2")
        if args.global_batch % max(n_pods - 1, 1):
            raise SystemExit(
                f"--global-batch {args.global_batch} must divide by the "
                f"surviving pod count {n_pods - 1}"
            )

        def recover(params, opt_state):
            from jax.sharding import Mesh
            from jax.sharding import PartitionSpec as P

            from repro.checkpoint.checkpointer import elastic_reshard

            # pod 0 died: keep the survivors' devices, same axis names
            old = holder["mesh"]
            surv = old.devices.shape[0] - 1
            new_mesh = Mesh(old.devices[1:], old.axis_names)
            # re-plan the pod sync on the shrunk topology from the USER'S
            # requested format (a planner pick on N pods shouldn't pin the
            # choice on N-1: crossovers flip as the DCN group shrinks)
            tcfg_req = dataclasses.replace(
                tcfg, pod_sync=args.pod_sync,
                bucket_bytes=args.bucket_bytes, overlap=overlap,
            )
            decision2 = train_steps.plan_pod_sync(
                cfg, tcfg_req, surv,
                chips_per_pod=new_mesh.devices.size // surv,
            )
            tcfg2 = dataclasses.replace(
                tcfg_req, pod_sync=decision2.fmt,
                bucket_bytes=decision2.bucket_bytes,
                overlap=decision2.overlap,
            )
            print(f"[train] re-planned on {surv} pod(s): "
                  f"{decision2.describe()}")
            step2, _ = train_steps.make_train_step(
                cfg, tcfg2, ocfg, new_mesh, pol
            )
            pspecs = rules.param_specs(cfg, params, pol)
            params = elastic_reshard(params, new_mesh, pspecs)
            opt_state = elastic_reshard(
                opt_state, new_mesh,
                type(opt_state)(step=P(), m=pspecs, v=pspecs),
            )
            holder["mesh"] = new_mesh
            holder["jitted"] = jax.jit(step2, donate_argnums=(0, 1))
            return stepper, params, opt_state

    lcfg = train_loop.LoopConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, log_every=10,
        lose_node_at_step=args.kill_pod_at,
    )
    t0 = time.time()
    state = train_loop.run(stepper, params, opt_state, data, lcfg,
                           recover=recover)
    dt = time.time() - t0
    tok_s = args.steps * args.global_batch * args.seq / dt
    for rec in state.recoveries:
        print(f"[train] elastic recovery: lost a pod at step "
              f"{rec['lost_at_step']}, resumed at {rec['resumed_at_step']} "
              f"in {rec['recovery_time_s']:.2f}s")
    print(f"[train] done: {args.steps} steps in {dt:.1f}s "
          f"({tok_s:,.0f} tok/s); loss {state.losses[0]:.3f} -> {state.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
