import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, extract cost/memory/collective statistics, write one JSON per cell.

MUST be run as its own process (the device-count flag above is set before
any other import, including jax).  Usage:

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \\
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached by cell name; --force recompiles.
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402
from pathlib import Path  # noqa: E402

import jax            # noqa: E402

from repro.configs import ALIASES, SHAPES, get_config, shape_applicable  # noqa: E402
from repro.core.topology import TOPOLOGY_PRESETS  # noqa: E402
from repro.launch import hlo_stats, specs  # noqa: E402
from repro.launch.mesh import POD_CHIPS, make_production_mesh  # noqa: E402


def run_cell(arch: str, shape_name: str, mesh_kind: str, outdir: Path,
             force: bool = False, pod_mode: str | None = None,
             pod_sync: str = "flat", accum=None, remat=None,
             policy: str = "default", topology: str = "v5e",
             overlap="off", compute_time: float = 0.0,
             tag: str = "") -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    suffix = f"_{tag}" if tag else ""
    out_path = outdir / f"{arch}_{shape_name}_{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    if not ok:
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                   skipped=True, reason=why)
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    t0 = time.time()
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind, skipped=False,
               n_devices=int(n_dev), tag=tag)
    try:
        kw = {}
        if shape.kind == "train":
            if pod_mode:
                kw["pod_mode"] = pod_mode
            kw["pod_sync"] = pod_sync
            if accum is not None:
                kw["accum"] = accum
            if remat is not None:
                kw["remat"] = remat
            if policy != "default":
                kw["policy"] = policy
            if topology != "v5e":
                kw["topology"] = topology
            if overlap != "off":
                kw["overlap"] = overlap
                kw["compute_time"] = compute_time
        cell = specs.build_cell(cfg, shape, mesh, **kw)
        rec["meta"] = cell.meta
        # jax.set_mesh only exists on newer jax; Mesh is itself a context
        # manager on the pinned version.
        mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
        with mesh_ctx:
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.meta.get("donate", ()),
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # pinned jax returns a one-element list of dicts; newer returns a dict
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)
        rec["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)
            ),
        }
        rec["memory"]["peak_per_device_bytes"] = (
            rec["memory"]["argument_bytes"]
            + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"]
        )
        flops = float(cost.get("flops", 0.0)) if cost else 0.0
        bytes_acc = float(cost.get("bytes accessed", 0.0)) if cost else 0.0
        rec["cost"] = {"flops": flops, "bytes_accessed": bytes_acc}

        hlo = compiled.as_text()
        import gzip
        (outdir / f"{arch}_{shape_name}_{mesh_kind}{suffix}.hlo.gz").write_bytes(
            gzip.compress(hlo.encode())
        )
        st = hlo_stats.parse_collectives(hlo, n_dev, POD_CHIPS)
        rec["collectives"] = {
            "by_kind": st.by_kind(),
            "wire_bytes_per_device": st.total_wire_bytes_per_device(),
            "wire_bytes_bf16_corrected": st.total_wire_bf16_corrected(),
            "pod_crossing_bytes_total": st.total_crossing_bytes(),
            "n_ops": len(st.ops),
        }
        rec["parser"] = "loop-aware-v2"
        rec["hlo_bytes"] = len(hlo)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 1)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--pod-mode", default=None, choices=[None, "gspmd", "manual"])
    ap.add_argument("--pod-sync", default="flat",
                    choices=["flat", "q8", "rs", "rs_q8", "auto"])
    ap.add_argument("--topology", default="v5e",
                    choices=sorted(TOPOLOGY_PRESETS),
                    help="topology preset for the pod-sync planner")
    ap.add_argument("--policy", default="default", choices=["default", "dp256"])
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--overlap", default="off",
                    help="compute/comm overlap for manual-mode train cells "
                         "('off' | 'auto' | int overlap depth)")
    ap.add_argument("--compute-time", type=float, default=0.0,
                    help="measured step compute seconds for the overlap "
                         "planner (0 = roofline estimate)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ALIASES:
            for shape in SHAPES:
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        assert args.arch and args.shape
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    n_ok = n_skip = n_fail = 0
    for arch, shape, mk in cells:
        rec = run_cell(arch, shape, mk, outdir, force=args.force,
                       pod_mode=args.pod_mode, pod_sync=args.pod_sync,
                       accum=args.accum, remat=args.remat,
                       policy=args.policy, topology=args.topology,
                       overlap=args.overlap,
                       compute_time=args.compute_time,
                       tag=args.tag)
        if rec.get("skipped"):
            n_skip += 1
            status = "SKIP"
        elif rec.get("ok"):
            n_ok += 1
            status = "OK"
        else:
            n_fail += 1
            status = "FAIL"
        mem = rec.get("memory", {}).get("peak_per_device_bytes", 0) / 2**30
        fl = rec.get("cost", {}).get("flops", 0)
        print(
            f"[{status}] {arch:20s} {shape:12s} {mk:6s} "
            f"mem/dev={mem:7.2f}GiB flops={fl:.3e} t={rec.get('total_s', 0)}s"
            + (
                ""
                if rec.get("ok") or rec.get("skipped")
                else f"  ERR={rec.get('error', '')[:120]}"
            ),
            flush=True,
        )
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
