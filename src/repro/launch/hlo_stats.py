"""Parse compiled (partitioned) HLO for collective statistics.

Extracts every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, with per-participant wire bytes (bandwidth-optimal
algorithm accounting) and, on multi-pod meshes, the bytes that must cross
the pod seam (the paper's *global edges*), computed from replica groups.

Device-id convention (launch/mesh.py): id = pod*256 + data*16 + model.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[0-9, {}]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def _shape_bytes(text: str) -> float:
    """Sum of sizes of all shapes in a shape string like
    '(f32[8,128], f32[8,128])' or 'bf16[2048,128]'."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str, n_devices: int) -> list[list[int]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, n = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = math.prod(dims)
        import numpy as np

        ids = np.arange(total).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(g, n)
        return ids.tolist()
    m = _GROUPS_LIST_RE.search(line)
    if m:
        inner = m.group(1)
        return [
            [int(x) for x in grp.split(",") if x.strip()]
            for grp in re.findall(r"\{([0-9, ]*)\}", inner)
        ]
    return [list(range(n_devices))]


@dataclass
class CollectiveOp:
    kind: str
    per_device_bytes: float      # operand bytes on one participant
    group_size: int
    n_groups: int
    wire_bytes_per_device: float
    crossing_bytes_total: float  # bytes crossing the pod seam (all groups)
    dtype: str = ""
    line: str = ""


@dataclass
class CollectiveStats:
    ops: list = field(default_factory=list)

    def total_wire_bytes_per_device(self) -> float:
        return sum(o.wire_bytes_per_device for o in self.ops)

    def total_crossing_bytes(self) -> float:
        return sum(o.crossing_bytes_total for o in self.ops)

    def total_wire_bf16_corrected(self) -> float:
        """TPU-corrected wire bytes: XLA:CPU's float-normalization upcasts
        bf16 values to f32, so f32 collectives on the CPU dry-run would be
        bf16 on TPU (our matmuls/activations are bf16; see EXPERIMENTS.md).
        Gradient reduce-scatters are genuinely f32 when accum_dtype=f32, so
        this is a lower bound; the raw number is the upper bound."""
        tot = 0.0
        for o in self.ops:
            f = 0.5 if o.dtype == "f32" else 1.0
            tot += o.wire_bytes_per_device * f
        return tot

    def by_kind(self) -> dict:
        agg = defaultdict(lambda: dict(count=0, wire=0.0, crossing=0.0))
        for o in self.ops:
            a = agg[o.kind]
            a["count"] += 1
            a["wire"] += o.wire_bytes_per_device
            a["crossing"] += o.crossing_bytes_total
        return dict(agg)


def _pod_of(dev: int, chips_per_pod: int) -> int:
    return dev // chips_per_pod


def _crossing_bytes(kind: str, groups, per_dev: float, chips_per_pod: int,
                    line: str = "") -> float:
    """Hierarchical-optimal bytes across the pod seam, per op (all groups)."""
    total = 0.0
    if kind == "collective-permute":
        m = _SRC_TGT_RE.search(line)
        if m:
            pairs = re.findall(r"\{(\d+),\s*(\d+)\}", m.group(0))
            for s, t in pairs:
                if _pod_of(int(s), chips_per_pod) != _pod_of(int(t), chips_per_pod):
                    total += per_dev
        return total
    for grp in groups:
        pods = defaultdict(int)
        for d in grp:
            pods[_pod_of(d, chips_per_pod)] += 1
        npods = len(pods)
        if npods <= 1:
            continue
        g = len(grp)
        if kind == "all-reduce":
            # hierarchical-optimal: one reduced partial crosses each seam in
            # each direction
            total += 2 * per_dev * (npods - 1)
        elif kind == "all-gather":
            # every pod must import the shards held by the other pods
            for cnt in pods.values():
                total += per_dev * (g - cnt)
        elif kind in ("reduce-scatter", "all-to-all"):
            # each participant's contribution homed in other pods crosses once
            for cnt in pods.values():
                total += cnt * per_dev * (g - cnt) / g
    return total


def _wire_bytes(kind: str, per_dev: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2 * per_dev * (g - 1) / g
    if kind == "all-gather":
        # per_dev = operand (shard); receives (g-1) shards
        return per_dev * (g - 1)
    if kind == "reduce-scatter":
        return per_dev * (g - 1) / g
    if kind == "all-to-all":
        return per_dev * (g - 1) / g
    if kind == "collective-permute":
        return per_dev
    return 0.0


_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_WHILE_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str) -> tuple:
    """-> (comps: name -> lines, entry_name)."""
    comps: dict[str, list] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{"):
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return comps, entry


def _loop_multipliers(hlo_text: str, comps: dict, entry=None) -> dict:
    """Estimated execution count per computation: product of trip counts of
    enclosing while loops.  Trip counts come from the largest constant in
    the loop's condition computation (the induction-variable bound); this is
    exact for scan-lowered loops.  Best effort, >= 1."""
    mult = {name: 1 for name in comps}

    # call graph: computation -> called computations
    calls: dict[str, set] = {name: set() for name in comps}
    for name, lines in comps.items():
        for line in lines:
            for callee in _CALL_RE.findall(line):
                if callee in comps:
                    calls[name].add(callee)

    # trip count per while body
    body_trip: dict[str, int] = {}
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line:
                mb = _WHILE_BODY_RE.search(line)
                mc = _WHILE_COND_RE.search(line)
                if not (mb and mc):
                    continue
                body, cond = mb.group(1), mc.group(1)
                trips = [int(x) for x in _TRIP_RE.findall(
                    "\n".join(comps.get(cond, []))
                )]
                body_trip[body] = max([t for t in trips if t > 1] or [1])

    # propagate multipliers down the call graph from ENTRY
    import collections

    roots = [entry] if entry else [n for n in comps
                                   if not any(n in c for c in calls.values())]
    seen: dict[str, int] = {}
    queue = collections.deque((r, 1) for r in roots if r)
    while queue:
        name, factor = queue.popleft()
        if seen.get(name, 0) >= factor:
            continue
        seen[name] = factor
        mult[name] = max(mult.get(name, 1), factor)
        for callee in calls.get(name, ()):  # body gets x trip count
            f = factor * body_trip.get(callee, 1)
            queue.append((callee, f))
    return mult


def parse_collectives(
    hlo_text: str, n_devices: int, chips_per_pod: int = 256
) -> CollectiveStats:
    """Loop-aware: collectives inside while bodies (layer scans, microbatch
    accumulation) are counted trip-count times."""
    stats = CollectiveStats()
    comps, entry = _split_computations(hlo_text)
    mult = _loop_multipliers(hlo_text, comps, entry)

    def scan_lines(lines, factor):
        for line in lines:
            m = _OP_RE.search(line)
            if not m:
                continue
            result_bytes = _shape_bytes(m.group(1))
            dm = _SHAPE_RE.search(m.group(1))
            dtype = dm.group(1) if dm else ""
            kind = m.group(2)
            groups = _parse_groups(line, n_devices)
            g = len(groups[0]) if groups else 1
            # derive the per-participant OPERAND bytes from the result shape
            if kind == "all-gather":
                per_dev = result_bytes / max(g, 1)
            elif kind == "reduce-scatter":
                per_dev = result_bytes * g
            else:
                per_dev = result_bytes
            stats.ops.append(
                CollectiveOp(
                    kind=kind,
                    per_device_bytes=per_dev,
                    group_size=g,
                    n_groups=len(groups),
                    wire_bytes_per_device=_wire_bytes(kind, per_dev, g) * factor,
                    crossing_bytes_total=_crossing_bytes(
                        kind, groups, per_dev, chips_per_pod, line
                    ) * factor,
                    dtype=dtype,
                    line=line.strip()[:200],
                )
            )

    if comps:
        for name, lines in comps.items():
            scan_lines(lines, mult.get(name, 1))
    else:
        scan_lines(hlo_text.splitlines(), 1)
    return stats
