"""Dispatching wrapper for RMSNorm: Pallas on TPU / interpret, jnp otherwise."""

from __future__ import annotations

import os

import jax

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    interpret = os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"
    if _on_tpu() or interpret:
        return kernel.rmsnorm(x, w, eps=eps, interpret=interpret)
    return ref.rmsnorm_reference(x, w, eps=eps)
