"""Pallas TPU fused RMSNorm kernel.

One [rows_block, D] VMEM tile per grid step: mean-square, rsqrt, and the
scale multiply fuse into a single HBM round-trip (vs 3 for the unfused op
sequence).  D is the model dim (lane-aligned multiples of 128 on TPU); rows
are (batch*seq) blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps, d):
    x = x_ref[...].astype(jnp.float32)          # [BR, D]
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(
    x: jax.Array,
    w: jax.Array,
    eps: float = 1e-5,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """x: [..., D]; w: [D]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    # VMEM budget: ~8 bytes/elem live (in+out, double-buffered); cap the row
    # block so the working set stays ~<=8 MiB of the 16 MiB VMEM
    block_rows = min(block_rows, max(8, (1 << 23) // (8 * d)))
    br = min(block_rows, rows)
    pad = (-rows) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out[:rows].reshape(orig_shape)
