"""Pure-jnp oracle for fused RMSNorm."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_reference(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Normalize the last dim in f32, scale by w, cast back."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
