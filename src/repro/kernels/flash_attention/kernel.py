"""Pallas TPU flash-attention kernel (blocked online softmax, GQA).

TPU adaptation notes (vs the CUDA flash-attention the technique comes from):
  * blocks are MXU-aligned: BQ x Dh and BK x Dh tiles with Dh padded to a
    multiple of 128; the [BQ, BK] logit tile feeds the 128x128 systolic
    array directly,
  * the online-softmax running state (m, l, acc) lives in VMEM scratch and
    is carried across the *sequential* innermost grid dimension (kv blocks),
    replacing CUDA's per-warp shared-memory accumulation,
  * no atomics / warp shuffles: the TPU grid is executed in order per core,
    so @pl.when(first/last kv block) handles init and finalization.

Validated with interpret=True on CPU against ``ref.mha_reference``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream: TPUCompilerParams (pinned jax) -> CompilerParams (newer)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, logit_softcap, sliding_window, bq, bk, seq_k,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                   # [BQ, Dh]
    k = k_ref[0, 0]                   # [BK, Dh]
    v = v_ref[0, 0]                   # [BK, Dh]

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                          # [BQ, BK]
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < seq_k                      # padding
    if causal:
        mask &= kpos <= qpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]                      # [BQ, 1]
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (all NEG_INF) against exp overflow/nan
    p = jnp.exp(logits - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _fini():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    logit_softcap: float = 0.0,
    sliding_window: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q: [B, Sq, H, Dh]; k, v: [B, Sk, Hkv, Dh] -> [B, Sq, H, Dh].

    Handles GQA via the k/v BlockSpec index map; pads S and Dh to block /
    lane multiples.  Sq must equal Sk (self-attention) for the causal path.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    g = H // Hkv
    scale = 1.0 / math.sqrt(Dh)

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    dh_pad = (-Dh) % 128 if not interpret else 0
    sq_pad = (-Sq) % bq
    sk_pad = (-Sk) % bk

    qp = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, dh_pad)))
    kp = jnp.pad(k, ((0, 0), (0, sk_pad), (0, 0), (0, dh_pad)))
    vp = jnp.pad(v, ((0, 0), (0, sk_pad), (0, 0), (0, dh_pad)))
    # layout: [B, H, S, Dh] so blocks are [S-block, Dh] tiles
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    Dp = Dh + dh_pad
    nq = (Sq + sq_pad) // bq
    nk = (Sk + sk_pad) // bk

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        logit_softcap=logit_softcap,
        sliding_window=sliding_window,
        bq=bq,
        bk=bk,
        seq_k=Sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dp), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, Dp), lambda b, h, iq, ik: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, Dp), lambda b, h, iq, ik: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dp), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + sq_pad, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dp), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qp, kp, vp)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :Sq, :, :Dh]
