"""Pure-jnp oracle for blocked causal GQA attention.

Materializes the full [B, H, Sq, Sk] logits -- use only at test scale.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def mha_reference(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    logit_softcap: float = 0.0,
    sliding_window: int = 0,
) -> jax.Array:
    """q: [B, Sq, H, Dh]; k, v: [B, Sk, Hkv, Dh] -> [B, Sq, H, Dh].

    GQA: query head h attends to kv head h // (H // Hkv).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    g = H // Hkv
    qh = q.reshape(B, Sq, Hkv, g, Dh)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qh, k, preferred_element_type=jnp.float32
    ) / math.sqrt(Dh)
    if logit_softcap:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)  # right-aligned
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if sliding_window:
        mask &= kpos > qpos - sliding_window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return o.reshape(B, Sq, H, Dh).astype(q.dtype)
