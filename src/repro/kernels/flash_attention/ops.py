"""Dispatching wrapper for attention.

Paths:
  * Pallas kernel (``kernel.flash_mha``)   -- TPU, or interpret=True in tests.
  * Chunked online-softmax in pure jnp     -- compiled path on CPU and the
    memory-sane fallback for long sequences (never materializes [Sq, Sk]).
  * Naive reference (``ref.mha_reference``) -- tiny shapes / oracle.
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp
from jax import lax

from . import ref
from .kernel import flash_mha

_CHUNK = 1024
NEG_INF = -1e30


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _chunked_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool,
    logit_softcap: float,
    sliding_window: int,
    chunk: int = _CHUNK,
) -> jax.Array:
    """Flash-style attention as a lax.scan over KV chunks (pure jnp).

    Identical math to the Pallas kernel; O(Sq * chunk) live memory.  Used as
    the compiled CPU path so that 32k-500k dry-runs have sane footprints.
    """
    B, Sq, H, Dh = q.shape
    _, Sk, Hkv, _ = k.shape
    g = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    ck = min(chunk, Sk)
    pad = (-Sk) % ck
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (Sk + pad) // ck
    kp = kp.reshape(B, nk, ck, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(B, nk, ck, Hkv, Dh).transpose(1, 0, 2, 3, 4)

    qh = q.reshape(B, Sq, Hkv, g, Dh)
    qpos = jnp.arange(Sq) + (Sk - Sq)

    def step(carry, xs):
        m, l, acc = carry
        ikc, kc, vc = xs
        logits = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qh, kc.astype(qh.dtype),
            preferred_element_type=jnp.float32,
        ) * scale
        if logit_softcap:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        kpos = ikc * ck + jnp.arange(ck)
        mask = kpos[None, :] < Sk
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if sliding_window:
            mask &= kpos[None, :] > qpos[:, None] - sliding_window
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_cur = jnp.max(logits, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.where(mask[None, None, None], jnp.exp(logits - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, g, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, Dh), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (jnp.arange(nk), kp, vp))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_diff(q, k, v, causal, logit_softcap, sliding_window, interpret):
    """Kernel forward + XLA (chunked) backward.

    Pallas cannot JVP through the scratch-carrying flash kernel; the
    standard pattern is a custom VJP: run the kernel forward, differentiate
    the mathematically identical chunked formulation for the backward."""
    return flash_mha(
        q, k, v, causal=causal, logit_softcap=logit_softcap,
        sliding_window=sliding_window, interpret=interpret,
    )


def _flash_diff_fwd(q, k, v, causal, logit_softcap, sliding_window, interpret):
    out = _flash_diff(q, k, v, causal, logit_softcap, sliding_window, interpret)
    return out, (q, k, v)


def _flash_diff_bwd(causal, logit_softcap, sliding_window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _chunked_mha(
            q_, k_, v_, causal, logit_softcap, sliding_window
        ),
        q, k, v,
    )
    return vjp(g)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    logit_softcap: float = 0.0,
    sliding_window: int = 0,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Attention entry point used by the model zoo."""
    if interpret is None:
        interpret = os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"
    if use_kernel and (_on_tpu() or interpret):
        return _flash_diff(
            q, k, v, causal, logit_softcap, sliding_window, interpret
        )
    if q.shape[1] * k.shape[1] <= 256 * 256:
        return ref.mha_reference(
            q, k, v, causal=causal, logit_softcap=logit_softcap,
            sliding_window=sliding_window,
        )
    return _chunked_mha(
        q, k, v, causal=causal, logit_softcap=logit_softcap,
        sliding_window=sliding_window,
    )
