"""Pure-jnp oracle for the Mamba2 selective state-space scan.

Sequential time recurrence (the mathematical definition):

    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t (x) x_t      (outer product)
    y_t = C_t . h_t + D_h * x_t

Shapes: x [B,S,H,P], dt [B,S,H] (positive), A [H] (negative), B/C [B,S,N],
D [H].  State h: [B,H,N,P].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def selective_scan_reference(x, dt, A, B, C, D) -> jax.Array:
    Bt, S, H, P = x.shape
    N = B.shape[-1]

    def step(h, t):
        xt = x[:, t].astype(jnp.float32)        # [B,H,P]
        dtt = dt[:, t].astype(jnp.float32)      # [B,H]
        Btv = B[:, t].astype(jnp.float32)       # [B,N]
        Ctv = C[:, t].astype(jnp.float32)       # [B,N]
        decay = jnp.exp(dtt * A)                # [B,H]
        upd = jnp.einsum("bn,bhp->bhnp", Btv, xt * dtt[..., None])
        h = h * decay[..., None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Ctv, h) + D[None, :, None] * xt
        return h, y

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    _, ys = lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # [B,S,H,P]
