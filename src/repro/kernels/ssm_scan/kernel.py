"""Pallas TPU kernel for the Mamba2 chunked selective scan (SSD form).

TPU adaptation: instead of a sequential per-timestep recurrence (GPU
mamba's warp-parallel scan), the sequence is split into chunks of Q steps.
Within a chunk everything is dense matmul ([Q,Q] decay-masked C@B^T and
[Q,N]x[N,P] state reads) that feeds the MXU; only the [N,P] chunk state
crosses chunk boundaries, carried in VMEM scratch across the sequential
innermost grid dimension.  This turns a bandwidth-bound scan into a
compute-dense blocked kernel -- the same insight as flash attention's
blocking, applied to SSMs.

Validated with interpret=True against ``ref.selective_scan_reference``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed upstream: TPUCompilerParams (pinned jax) -> CompilerParams (newer)
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, da_ref, dt_ref, b_ref, c_ref, o_ref, state_scr, *, q):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    a = da_ref[0, 0].astype(jnp.float32)         # [Q]   (dt * A, negative)
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q]
    Bc = b_ref[0].astype(jnp.float32)            # [Q, N]
    Cc = c_ref[0].astype(jnp.float32)            # [Q, N]

    cum = jnp.cumsum(a)                          # [Q]
    # intra-chunk: y_j += sum_{i<=j} exp(cum_j - cum_i) (C_j.B_i) dt_i x_i
    G = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                            # [j, i]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    seg = jnp.where(jj >= ii, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    W = G * seg                                  # [Q, Q]
    y = jax.lax.dot_general(
        W, x * dt[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # [Q, P]
    # inter-chunk: y_j += C_j exp(cum_j) . h_prev
    h_prev = state_scr[...]                      # [N, P]
    y += jax.lax.dot_general(
        Cc * jnp.exp(cum)[:, None], h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0] = y.astype(o_ref.dtype)
    # state update: h = exp(cum_last) h_prev + sum_i exp(cum_last-cum_i) dt_i B_i (x) x_i
    w = jnp.exp(cum[-1] - cum) * dt              # [Q]
    state_scr[...] = jnp.exp(cum[-1]) * h_prev + jax.lax.dot_general(
        Bc * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    D: jax.Array,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Chunked selective scan.  Same shapes as the reference.

    x [Bt,S,H,P], dt [Bt,S,H], A [H], B/C [Bt,S,N], D [H] -> y [Bt,S,H,P].
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, S)
    pad = (-S) % q
    Sp = S + pad
    nC = Sp // q

    xt = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    dtp = jnp.pad(dt, ((0, 0), (0, pad), (0, 0))).transpose(0, 2, 1)  # [B,H,S]
    da = dtp * A[None, :, None]
    Bp = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
    Cp = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        grid=(Bt, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, q, P), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, q), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1, 1, q), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1, q, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, q, N), lambda b, h, ic: (b, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, P), lambda b, h, ic: (b, h, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((Bt, H, Sp, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xt, da, dtp, Bp, Cp)
    y = out.transpose(0, 2, 1, 3)[:, :S]
    return (y + D[None, None, :, None] * x).astype(x.dtype)
