"""Dispatching wrapper for the selective scan.

The chunked-jnp path mirrors the kernel's SSD math with lax.scan over
chunks -- compiled CPU path with compact HLO (one chunk body), used by the
dry-run so 500k-sequence lowering stays small.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax import lax

from . import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _chunked_jnp(x, dt, A, B, C, D, chunk: int = 128) -> jax.Array:
    """SSD chunked scan in pure jnp (same math as the Pallas kernel)."""
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, S)
    pad = (-S) % q
    Sp = S + pad
    nC = Sp // q
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Bf = jnp.pad(B.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Cf = jnp.pad(C.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    xc = xf.reshape(Bt, nC, q, H, P).transpose(1, 0, 3, 2, 4)   # [nC,B,H,Q,P]
    dtc = dtf.reshape(Bt, nC, q, H).transpose(1, 0, 3, 2)        # [nC,B,H,Q]
    Bc = Bf.reshape(Bt, nC, q, N).transpose(1, 0, 2, 3)          # [nC,B,Q,N]
    Cc = Cf.reshape(Bt, nC, q, N).transpose(1, 0, 2, 3)

    ii = jnp.arange(q)[None, :]
    jj = jnp.arange(q)[:, None]
    causal = jj >= ii

    def step(h, xs):
        xq, dtq, bq, cq = xs                     # [B,H,Q,P],[B,H,Q],[B,Q,N]x2
        a = dtq * A[None, :, None]               # [B,H,Q]
        cum = jnp.cumsum(a, axis=-1)
        G = jnp.einsum("bjn,bin->bji", cq, bq)   # [B,Q,Q]
        seg = jnp.where(
            causal[None, None],
            jnp.exp(cum[..., :, None] - cum[..., None, :]),
            0.0,
        )                                        # [B,H,Q,Q]
        W = G[:, None] * seg
        y = jnp.einsum("bhji,bhip->bhjp", W, xq * dtq[..., None])
        y += jnp.einsum(
            "bjn,bhj,bhnp->bhjp", cq, jnp.exp(cum), h
        )
        w = jnp.exp(cum[..., -1:] - cum) * dtq   # [B,H,Q]
        h = (
            jnp.exp(cum[..., -1])[..., None, None] * h
            + jnp.einsum("bin,bhi,bhip->bhnp", bq, w, xq)
        )
        return h, y

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    _, ys = lax.scan(step, h0, (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(Bt, Sp, H, P)[:, :S]
    return (y + D[None, None, :, None] * x.astype(jnp.float32)).astype(x.dtype)


def selective_scan(x, dt, A, B, C, D, chunk: int = 128) -> jax.Array:
    interpret = os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"
    if _on_tpu() or interpret:
        return kernel.ssd_scan(x, dt, A, B, C, D, chunk=chunk, interpret=interpret)
    if x.shape[1] <= 64:
        return ref.selective_scan_reference(x, dt, A, B, C, D)
    return _chunked_jnp(x, dt, A, B, C, D, chunk=chunk)


def final_state(x, dt, A, B, chunk: int = 128) -> jax.Array:
    """Final SSM state after scanning the whole sequence (for prefill).

    h_S = sum_i exp(sum_{k>i} a_k) dt_i B_i (x) x_i, computed chunk-wise.
    Returns [Bt, H, N, P] f32.
    """
    Bt, S, H, P = x.shape
    N = B.shape[-1]
    q = min(chunk, S)
    pad = (-S) % q
    Sp = S + pad
    nC = Sp // q
    xf = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad), (0, 0), (0, 0)))
    dtf = jnp.pad(dt.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    Bf = jnp.pad(B.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    xc = xf.reshape(Bt, nC, q, H, P).transpose(1, 0, 3, 2, 4)
    dtc = dtf.reshape(Bt, nC, q, H).transpose(1, 0, 3, 2)
    Bc = Bf.reshape(Bt, nC, q, N).transpose(1, 0, 2, 3)

    def step(h, xs):
        xq, dtq, bq = xs
        a = dtq * A[None, :, None]
        cum = jnp.cumsum(a, axis=-1)
        w = jnp.exp(cum[..., -1:] - cum) * dtq
        h = (
            jnp.exp(cum[..., -1])[..., None, None] * h
            + jnp.einsum("bin,bhi,bhip->bhnp", bq, w, xq)
        )
        return h, None

    h0 = jnp.zeros((Bt, H, N, P), jnp.float32)
    h, _ = lax.scan(step, h0, (xc, dtc, Bc))
    return h


def selective_scan_with_state(x, dt, A, B, C, D, chunk: int = 128):
    """(y, final_state) -- y via the dispatched path, state via chunked jnp."""
    y = selective_scan(x, dt, A, B, C, D, chunk=chunk)
    return y, final_state(x, dt, A, B, chunk=chunk)


def decode_step(x, dt, A, B, C, D, state):
    """Single-token state update for serving.

    x [Bt,H,P], dt [Bt,H], B/C [Bt,N], state [Bt,H,N,P] ->
    (y [Bt,H,P], new_state).
    """
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)                     # [B,H]
    upd = jnp.einsum("bn,bhp->bhnp", B.astype(jnp.float32), xf * dtf[..., None])
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), state)
    y = y + D[None, :, None] * xf
    return y.astype(x.dtype), state
