"""Sharded checkpointing with async writes, manifest validation, and
elastic re-meshing.

Layout per step::

    <dir>/step_000100/
        manifest.json          {step, leaf index, shapes, dtypes, crc}
        arrays.npz             one entry per flattened leaf path

Fault-tolerance contract:
  * writes go to ``step_N.tmp/`` and are atomically renamed -- a crash
    mid-write never corrupts the latest checkpoint;
  * ``latest_step`` scans for the newest *complete* manifest (rename is the
    commit point) and validates the per-leaf CRCs on restore;
  * the async writer runs on a daemon thread; ``wait()`` joins before the
    next save so at most one write is in flight (bounded memory);
  * restore accepts a different data-parallel world size (elastic): arrays
    are saved unsharded (host-gathered), so any mesh can reload them --
    re-sharding happens at the first ``jit`` invocation via in_shardings.
    (On a real multi-host pod each host writes its own shard set; the
    single-process layout here keeps the same manifest format.)
"""

from __future__ import annotations

import json
import shutil
import threading
import time
import zipfile
import zlib
from pathlib import Path

import jax
import numpy as np


class CheckpointCorruptError(IOError):
    """A committed checkpoint failed validation (truncated arrays, missing
    manifest entries, CRC mismatch).  Names the bad step so operators can
    quarantine it; ``restore`` falls back to the previous complete step
    automatically when the step wasn't explicitly requested."""

    def __init__(self, step: int, reason: str):
        super().__init__(f"checkpoint step {step} is corrupt: {reason}")
        self.step = step
        self.reason = reason


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        out[key] = leaf
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        """Snapshot ``tree`` at ``step``.  Device arrays are fetched to host
        first (cheap for CPU; device-to-host DMA on TPU) so training can
        continue while the writer thread serializes."""
        self.wait()
        flat, _ = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **host)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": {
                    k: {
                        "shape": list(v.shape),
                        "dtype": str(v.dtype),
                        "crc": zlib.crc32(np.ascontiguousarray(v).tobytes()),
                    }
                    for k, v in host.items()
                },
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)   # commit point
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self._complete_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def _complete_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return out

    def latest_step(self) -> int | None:
        steps = self._complete_steps()
        return max(steps) if steps else None

    def restore(self, tree_like, step: int | None = None, validate: bool = True):
        """Restore into the structure of ``tree_like`` (arrays or SDS).

        With ``step=None`` (the usual resume path), a checkpoint that
        committed but is damaged on disk -- truncated ``arrays.npz``,
        missing manifest or array entries, CRC mismatch -- is skipped with
        a fallback to the next-older complete step, so one bad snapshot
        (e.g. a crash racing the final fsync) never bricks a resume.  An
        explicitly requested ``step`` raises ``CheckpointCorruptError``
        instead: the caller asked for that exact state.
        """
        if step is not None:
            return self._restore_step(tree_like, step, validate)
        candidates = sorted(self._complete_steps(), reverse=True)
        if not candidates:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        errors: list[CheckpointCorruptError] = []
        for cand in candidates:
            try:
                return self._restore_step(tree_like, cand, validate)
            except CheckpointCorruptError as exc:
                errors.append(exc)
        raise CheckpointCorruptError(
            errors[0].step,
            "every complete checkpoint failed validation: "
            + "; ".join(e.reason for e in errors),
        )

    def _restore_step(self, tree_like, step: int, validate: bool):
        d = self.dir / f"step_{step:08d}"
        if not (d / "manifest.json").exists():
            raise FileNotFoundError(f"no checkpoint for step {step} in {self.dir}")
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError) as exc:
            raise CheckpointCorruptError(step, f"unreadable manifest: {exc}")
        try:
            data = np.load(d / "arrays.npz")
            npz_keys = set(data.files)
        except (OSError, ValueError, zipfile.BadZipFile) as exc:
            raise CheckpointCorruptError(
                step, f"unreadable arrays.npz (truncated write?): {exc}"
            )
        flat, _ = _flatten(tree_like)
        leaves = []
        for key, like in flat.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise CheckpointCorruptError(
                    step, f"manifest is missing leaf {key!r}"
                )
            if key not in npz_keys:
                raise CheckpointCorruptError(
                    step, f"arrays.npz is missing leaf {key!r}"
                )
            try:
                arr = data[key]
            except (OSError, ValueError, zipfile.BadZipFile) as exc:
                raise CheckpointCorruptError(
                    step, f"leaf {key!r} is unreadable (truncated?): {exc}"
                )
            if list(arr.shape) != list(meta["shape"]):
                raise CheckpointCorruptError(
                    step,
                    f"leaf {key!r} truncated: manifest says {meta['shape']}, "
                    f"file holds {list(arr.shape)}",
                )
            if validate:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc"]:
                    raise CheckpointCorruptError(
                        step, f"checksum mismatch for {key}"
                    )
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs {like.shape}"
                )
            leaves.append(arr)
        # order of _flatten matches tree flatten order
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(tree_like), leaves
        ), step


def elastic_reshard(tree, mesh, spec_tree):
    """Place a host-restored tree onto a (possibly different-size) mesh.

    The elastic path after a topology change: restore on host, then device_put
    with the new mesh's NamedShardings.  Data-parallel size changes need no
    array surgery (DP shards are replicas); tensor-parallel changes re-slice.
    """
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
        tree,
        spec_tree,
        is_leaf=lambda x: x is None,
    )
