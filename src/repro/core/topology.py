"""Cluster topology description for the multi-core communication model.

The paper's object of study is a cluster of machines, each machine holding
several processes that share memory and share the machine's external network
links.  We keep the paper's vocabulary (machine / process / degree) and map it
onto the TPU hierarchy (pod / chip / pod-egress links) via presets at the
bottom of this file.

The paper's Rule 2 models exactly two link tiers; real hardware has more
(v5e: ICI hop / host PCIe / DCN), so ``ClusterTopology`` is a general *tier
hierarchy*: an ordered tuple of ``LinkTier``s from the innermost (fastest,
tier 0 -- the shared-memory tier Rule 1 writes live on) to the outermost
(slowest, the shared-NIC tier Rule 3 guards), plus a ``fanout`` tuple giving
the branching factor at every level.  Process ids are flat; their
hierarchical coordinates are derived (``coords`` / ``group_of`` /
``tier_index``).  The two-tier construction of the paper stays a one-liner
(``ClusterTopology.two_tier`` or the legacy keyword form), and
``local`` / ``global_`` / ``n_machines`` / ``procs_per_machine`` survive as
derived properties so every two-tier call site keeps working unchanged.

Everything here is plain Python (no jax) so the planner can run anywhere,
including inside launcher processes before jax initializes devices.
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class LinkTier:
    """One tier of the tiered network (generalizing paper Rule 2).

    alpha:  per-message startup latency, seconds.
    beta:   per-byte transfer time, seconds/byte (1 / bandwidth).
    """

    name: str
    alpha: float
    beta: float

    @property
    def bandwidth(self) -> float:
        return 1.0 / self.beta

    def transfer_time(self, nbytes: float) -> float:
        return self.alpha + nbytes * self.beta


@dataclass(frozen=True, init=False)
class ClusterTopology:
    """A homogeneous cluster with a hierarchy of link tiers.

    tiers:         link tiers, innermost (tier 0, shared memory / ICI) to
                   outermost (the machine-boundary tier, e.g. DCN).  Rule 2
                   generalized: every inner tier is at least as fast as the
                   tier outside it (alpha and beta both).
    fanout:        branching factors, aligned with ``tiers``: ``fanout[l]``
                   level-``l`` groups form one level-``l+1`` group, linked by
                   tier ``l``.  A level-0 group is a single process; the
                   level-``len(fanout)`` group is the whole cluster.
    degree:        external links usable *simultaneously* by one machine
                   (paper Rule 3; TPU: host NICs per pod).  Applies to the
                   outermost tier.
    degrees:       per-tier Rule-3 link counts, aligned with ``tiers``:
                   ``degrees[l]`` is the number of tier-``l`` links a
                   level-``l`` group can drive simultaneously (0 = unlimited,
                   the classic assumption for the inner shared-memory / ICI
                   tiers).  Defaults to unlimited everywhere except the
                   outermost tier, which carries ``degree`` -- so two-tier
                   behaviour is exactly the paper's Rule 3.
    write_cost:    constant time for a shared-memory write visible to any
                   subset of tier-0 co-located processes (Rule 1, "write").
    assemble_cost: per-message assembly time charged when a process's buffer
                   must be *read* (Rule 1, "read").

    The classic two-tier cluster of the paper is ``tiers=(local, global_)``,
    ``fanout=(procs_per_machine, n_machines)``; the legacy keyword
    constructor (``n_machines= / procs_per_machine= / local= / global_=``)
    and the ``two_tier`` classmethod both build exactly that.
    """

    tiers: tuple
    fanout: tuple
    degree: int
    write_cost: float
    assemble_cost: float
    degrees: tuple

    def __init__(
        self,
        n_machines: int | None = None,
        procs_per_machine: int | None = None,
        degree: int | None = None,
        local: LinkTier | None = None,
        global_: LinkTier | None = None,
        write_cost: float | None = None,
        assemble_cost: float = 0.0,
        *,
        tiers: tuple | None = None,
        fanout: tuple | None = None,
        degrees: tuple | None = None,
    ) -> None:
        # degree and write_cost stay REQUIRED (as in the pre-tier-list
        # dataclass): a defaulted write_cost of 0 would silently model
        # Rule-1 shared-memory writes as free and skew strategy rankings.
        if degree is None and degrees is not None:
            degree = int(degrees[-1])
        if degree is None:
            raise ValueError("degree is required")
        if write_cost is None:
            raise ValueError("write_cost is required")
        if (tiers is None) != (fanout is None):
            raise ValueError("tiers and fanout must be given together")
        if tiers is not None:
            if any(x is not None for x in (n_machines, procs_per_machine,
                                           local, global_)):
                raise ValueError(
                    "pass either the tier-list form (tiers=, fanout=) or the "
                    "legacy two-tier keywords, not both"
                )
            tiers = tuple(tiers)
            fanout = tuple(int(f) for f in fanout)
        else:
            if local is None or global_ is None or n_machines is None \
                    or procs_per_machine is None:
                raise ValueError(
                    "two-tier construction needs n_machines, "
                    "procs_per_machine, local and global_"
                )
            tiers = (local, global_)
            fanout = (int(procs_per_machine), int(n_machines))
        if degrees is None:
            degrees = (0,) * (len(tiers) - 1) + (int(degree),)
        else:
            degrees = tuple(int(d) for d in degrees)
        object.__setattr__(self, "tiers", tiers)
        object.__setattr__(self, "fanout", fanout)
        object.__setattr__(self, "degree", int(degree))
        object.__setattr__(self, "write_cost", float(write_cost))
        object.__setattr__(self, "assemble_cost", float(assemble_cost))
        object.__setattr__(self, "degrees", degrees)
        self._check()

    def _check(self) -> None:
        if len(self.tiers) != len(self.fanout):
            raise ValueError(
                f"tiers ({len(self.tiers)}) and fanout ({len(self.fanout)}) "
                "must have the same length"
            )
        if len(self.tiers) < 2:
            raise ValueError("a cluster has at least two tiers")
        if any(f < 1 for f in self.fanout):
            raise ValueError(f"fanout entries must be >= 1, got {self.fanout}")
        if self.degree < 1:
            raise ValueError("degree must be >= 1")
        if len(self.degrees) != len(self.tiers):
            raise ValueError(
                f"degrees ({len(self.degrees)}) and tiers "
                f"({len(self.tiers)}) must have the same length"
            )
        if any(d < 0 for d in self.degrees):
            raise ValueError(
                f"degrees entries must be >= 0 (0 = unlimited), got "
                f"{self.degrees}"
            )
        if self.degrees[-1] != self.degree:
            raise ValueError(
                f"degrees[-1] ({self.degrees[-1]}) must equal the outermost "
                f"degree ({self.degree})"
            )
        for inner, outer in zip(self.tiers, self.tiers[1:]):
            if inner.alpha > outer.alpha or inner.beta > outer.beta:
                # Rule 2 generalized: inner edges are short, outer edges long.
                raise ValueError(
                    f"tier {inner.name!r} must be at least as fast as the "
                    f"tier {outer.name!r} outside it"
                )

    @classmethod
    def two_tier(
        cls,
        n_machines: int,
        procs_per_machine: int,
        degree: int,
        local: LinkTier,
        global_: LinkTier,
        write_cost: float,
        assemble_cost: float = 0.0,
    ) -> "ClusterTopology":
        """The paper's two-tier cluster, spelled out (one-liner form)."""
        return cls(
            tiers=(local, global_),
            fanout=(procs_per_machine, n_machines),
            degree=degree,
            write_cost=write_cost,
            assemble_cost=assemble_cost,
        )

    # ------------------------------------------------------------------
    # hierarchical coordinates
    # ------------------------------------------------------------------
    @property
    def n_tiers(self) -> int:
        return len(self.tiers)

    @property
    def n_procs(self) -> int:
        return math.prod(self.fanout)

    def group_size(self, level: int) -> int:
        """Processes per level-``level`` group (level 0 = one process)."""
        return math.prod(self.fanout[:level])

    def group_of(self, proc: int, level: int) -> int:
        """Index of the level-``level`` group containing ``proc``."""
        return proc // self.group_size(level)

    def group_procs(self, level: int, group: int) -> range:
        base = group * self.group_size(level)
        return range(base, base + self.group_size(level))

    def coords(self, proc: int) -> tuple:
        """Per-level coordinates, innermost first: coords[l] in fanout[l]."""
        out = []
        for f in self.fanout:
            out.append(proc % f)
            proc //= f
        return tuple(out)

    def tier_index(self, p: int, q: int) -> int:
        """The tier over which distinct procs p and q communicate: the level
        of their outermost differing coordinate."""
        for level in range(self.n_tiers - 1, -1, -1):
            if self.group_of(p, level + 1) != self.group_of(q, level + 1):
                raise ValueError(f"procs {p} and {q} share no group")
            if self.group_of(p, level) != self.group_of(q, level):
                return level
        raise ValueError(f"tier_index({p}, {q}): procs coincide")

    def tier(self, p: int, q: int) -> LinkTier:
        return self.tiers[self.tier_index(p, q)]

    def tier_degree(self, level: int) -> int:
        """Rule-3 parallel links a level-``level`` group drives on tier
        ``level`` (0 = unlimited; the outermost entry is ``degree``)."""
        return self.degrees[level]

    # ------------------------------------------------------------------
    # two-tier view (machine = outermost group) -- back-compat surface
    # ------------------------------------------------------------------
    @property
    def local(self) -> LinkTier:
        return self.tiers[0]

    @property
    def global_(self) -> LinkTier:
        return self.tiers[-1]

    @property
    def n_machines(self) -> int:
        return self.fanout[-1]

    @property
    def procs_per_machine(self) -> int:
        return math.prod(self.fanout[:-1])

    def machine_of(self, proc: int) -> int:
        return proc // self.procs_per_machine

    def procs_of(self, machine: int) -> range:
        base = machine * self.procs_per_machine
        return range(base, base + self.procs_per_machine)

    def co_located(self, p: int, q: int) -> bool:
        return self.machine_of(p) == self.machine_of(q)

    def inner_group_of(self, proc: int) -> int:
        """Index of proc's tier-0 (shared-memory) group."""
        return proc // self.fanout[0]

    def inner_peers(self, proc: int) -> range:
        """Procs sharing ``proc``'s tier-0 (shared-memory) group."""
        base = self.inner_group_of(proc) * self.fanout[0]
        return range(base, base + self.fanout[0])

    # ------------------------------------------------------------------
    # round-based view (telephone model + the paper's three rules)
    # ------------------------------------------------------------------
    def global_round_time(self, nbytes: float) -> float:
        """Duration of one *global* round for an nbytes message.

        Paper: "we'll assume any number of internal edges may be traversed
        during a single round and include this extra cost in our round length
        estimate" -- the round length is the global transfer plus the local
        slack that hides any intra-machine pattern.
        """
        local_slack = self.write_cost + math.ceil(
            math.log2(max(self.procs_per_machine, 2))
        ) * self.local.transfer_time(nbytes)
        return self.global_.transfer_time(nbytes) + self.assemble_cost + local_slack

    def local_round_time(self, nbytes: float) -> float:
        """Duration of one *local* round (one clique edge, Rule 1 'read')."""
        return self.local.transfer_time(nbytes) + self.assemble_cost

    def with_(self, **kw) -> "ClusterTopology":
        """Functional update; accepts the tier-list fields AND the legacy
        two-tier names (n_machines / procs_per_machine / local / global_),
        which are mapped onto the tier structure."""
        tiers = list(kw.pop("tiers", self.tiers))
        fanout = list(kw.pop("fanout", self.fanout))
        degrees = kw.pop("degrees", None)
        if "local" in kw:
            tiers[0] = kw.pop("local")
        if "global_" in kw:
            tiers[-1] = kw.pop("global_")
        if "n_machines" in kw:
            fanout[-1] = int(kw.pop("n_machines"))
        if "procs_per_machine" in kw:
            c = int(kw.pop("procs_per_machine"))
            if len(fanout) == 2:
                fanout[0] = c
            elif math.prod(fanout[:-1]) != c:
                raise ValueError(
                    f"procs_per_machine={c} is ambiguous on a "
                    f"{len(fanout)}-tier topology (inner fanout "
                    f"{tuple(fanout[:-1])}); pass fanout= instead"
                )
        degree = kw.pop(
            "degree", int(degrees[-1]) if degrees is not None else self.degree
        )
        write_cost = kw.pop("write_cost", self.write_cost)
        assemble_cost = kw.pop("assemble_cost", self.assemble_cost)
        if degrees is None and len(tiers) == self.n_tiers:
            # keep any per-tier inner degrees; the outermost tracks degree
            degrees = self.degrees[:-1] + (int(degree),)
        if kw:
            raise TypeError(f"unknown ClusterTopology fields {sorted(kw)}")
        return ClusterTopology(
            tiers=tuple(tiers),
            fanout=tuple(fanout),
            degree=degree,
            write_cost=write_cost,
            assemble_cost=assemble_cost,
            degrees=tuple(degrees) if degrees is not None else None,
        )

    # ------------------------------------------------------------------
    # degraded-topology functional updates (the fault layer's surface)
    # ------------------------------------------------------------------
    def degraded(
        self,
        tier: int | str = -1,
        *,
        beta_scale: float = 1.0,
        alpha_add: float = 0.0,
        degree_drop: int = 0,
    ) -> "ClusterTopology":
        """This topology with one tier's links degraded.

        ``tier`` selects the degraded level by index (negative indices OK)
        or by name; ``beta_scale`` divides the tier's bandwidth (2.0 = half
        bandwidth), ``alpha_add`` adds startup latency (a latency spike),
        and ``degree_drop`` removes that many of the tier's Rule-3 parallel
        links (only meaningful where ``degrees[tier] > 0``).

        A degraded inner tier also bounds every message routed over the
        tiers outside it, so outer tiers are lifted to stay at least as
        slow (Rule-2 monotonicity is preserved instead of violated).
        Re-planning on the returned topology is the whole point: strategy
        crossovers shift when per-tier alpha/beta shift.
        """
        if beta_scale < 1.0 or alpha_add < 0.0:
            raise ValueError(
                "degraded() only degrades: beta_scale >= 1 and "
                f"alpha_add >= 0, got {beta_scale}/{alpha_add}"
            )
        tix = self._tier_index_of(tier)
        tiers = list(self.tiers)
        t = tiers[tix]
        tiers[tix] = LinkTier(
            t.name, alpha=t.alpha + alpha_add, beta=t.beta * beta_scale
        )
        for j in range(tix + 1, len(tiers)):
            outer = tiers[j]
            tiers[j] = LinkTier(
                outer.name,
                alpha=max(outer.alpha, tiers[j - 1].alpha),
                beta=max(outer.beta, tiers[j - 1].beta),
            )
        degrees = list(self.degrees)
        if degree_drop:
            if degrees[tix] == 0:
                raise ValueError(
                    f"tier {tix} has unlimited links; degree_drop needs a "
                    "finite Rule-3 degree"
                )
            degrees[tix] = max(1, degrees[tix] - int(degree_drop))
        return ClusterTopology(
            tiers=tuple(tiers),
            fanout=self.fanout,
            degree=degrees[-1],
            write_cost=self.write_cost,
            assemble_cost=self.assemble_cost,
            degrees=tuple(degrees),
        )

    def shrunk(self, lost_nodes, level: int | None = None) -> "ClusterTopology":
        """The surviving topology after losing whole outermost groups.

        ``lost_nodes`` is either a count of lost level-``level`` groups
        (default: outermost -- machines/pods) or an iterable of lost *proc*
        ids, which are mapped to the distinct groups containing them (a
        homogeneous topology only cares how many survive, not which).  The
        elastic-recovery path plans pod sync on this shape after node loss.
        """
        if level is None:
            level = self.n_tiers - 1
        if not 0 <= level < self.n_tiers:
            raise ValueError(f"level {level} out of range")
        if isinstance(lost_nodes, int):
            n_lost = lost_nodes
        else:
            n_lost = len({self.group_of(int(p), level) for p in lost_nodes})
        if n_lost < 0:
            raise ValueError(f"lost_nodes must be >= 0, got {n_lost}")
        survivors = self.fanout[level] - n_lost
        if survivors < 1:
            raise ValueError(
                f"cannot lose {n_lost} of {self.fanout[level]} "
                f"level-{level} groups: no survivors"
            )
        fanout = list(self.fanout)
        fanout[level] = survivors
        return ClusterTopology(
            tiers=self.tiers,
            fanout=tuple(fanout),
            degree=self.degree,
            write_cost=self.write_cost,
            assemble_cost=self.assemble_cost,
            degrees=self.degrees,
        )

    def _tier_index_of(self, tier: int | str) -> int:
        """Resolve a tier selector (index, negative index, or name)."""
        if isinstance(tier, str):
            for i, t in enumerate(self.tiers):
                if t.name == tier:
                    return i
            raise ValueError(
                f"no tier named {tier!r} "
                f"(have {[t.name for t in self.tiers]})"
            )
        tix = int(tier)
        if tix < 0:
            tix += self.n_tiers
        if not 0 <= tix < self.n_tiers:
            raise ValueError(f"tier index {tier} out of range")
        return tix

    def with_shape(self, fanout, degree: int | None = None) -> "ClusterTopology":
        """Same tier parameters on a different shape.

        ``fanout`` may be *shorter* than this topology's (a truncated
        calibration stage): the innermost ``len(fanout)`` tiers are kept.
        """
        fanout = tuple(int(f) for f in fanout)
        if len(fanout) > self.n_tiers:
            raise ValueError(
                f"shape {fanout} has more levels than the {self.n_tiers} "
                "link tiers"
            )
        degree = self.degree if degree is None else int(degree)
        return ClusterTopology(
            tiers=self.tiers[: len(fanout)],
            fanout=fanout,
            degree=degree,
            write_cost=self.write_cost,
            assemble_cost=self.assemble_cost,
            degrees=self.degrees[: len(fanout) - 1] + (degree,),
        )

    def stage(self, level: int) -> "ClusterTopology":
        """The calibration sub-topology exercising tiers 0..level-1 only:
        one level-``level`` group, outermost extent 1.  ``stage(1)`` is the
        single-machine local-tier stage of the two-tier workflow."""
        if not 1 <= level < self.n_tiers:
            raise ValueError(
                f"stage level must be in [1, {self.n_tiers - 1}], got {level}"
            )
        return self.with_shape(self.fanout[:level] + (1,))

    # ------------------------------------------------------------------
    # calibration interface
    # ------------------------------------------------------------------
    def param_vector(self) -> tuple:
        """The model's free parameters as the canonical fit vector.

        Order matches ``simulator.cost_features`` / ``comm.calibrate``:
        (alpha_0, beta_0, ..., alpha_{T-1}, beta_{T-1}, write_cost,
        assemble_cost) -- 2 * n_tiers + 2 entries, tier 0 innermost.  For a
        two-tier topology this is the historical (local.alpha, local.beta,
        global.alpha, global.beta, write_cost, assemble_cost).
        """
        out = []
        for t in self.tiers:
            out.extend((t.alpha, t.beta))
        out.extend((self.write_cost, self.assemble_cost))
        return tuple(out)

    @classmethod
    def fitted_tiers(
        cls,
        fanout,
        degree: int,
        *,
        alphas,
        betas,
        write_cost: float,
        assemble_cost: float = 0.0,
        names=None,
    ) -> "ClusterTopology":
        """Topology from empirically fitted per-tier parameters.

        Measured fits can come back degenerate (negative intercepts from
        noise, or an inner tier that probed slower than an outer one on
        hardware where tiers share a NIC), so this constructor projects onto
        the model's feasible region instead of raising: every parameter is
        floored at a small positive epsilon and each tier is clamped to be
        at least as fast as the tier outside it (Rule 2, applied outermost
        inwards).
        """
        fanout = tuple(int(f) for f in fanout)
        T = len(fanout)
        alphas = [max(a, _FIT_ALPHA_FLOOR) for a in alphas]
        betas = [max(b, _FIT_BETA_FLOOR) for b in betas]
        if len(alphas) != T or len(betas) != T:
            raise ValueError(
                f"need {T} alphas and betas for fanout {fanout}, got "
                f"{len(alphas)}/{len(betas)}"
            )
        for i in range(T - 2, -1, -1):
            alphas[i] = min(alphas[i], alphas[i + 1])
            betas[i] = min(betas[i], betas[i + 1])
        if names is None:
            names = (
                ("local_fit", "global_fit")
                if T == 2
                else tuple(f"tier{i}_fit" for i in range(T))
            )
        return cls(
            tiers=tuple(
                LinkTier(n, alpha=a, beta=b)
                for n, a, b in zip(names, alphas, betas)
            ),
            fanout=fanout,
            degree=degree,
            write_cost=max(write_cost, _FIT_ALPHA_FLOOR),
            assemble_cost=max(assemble_cost, 0.0),
        )

    @classmethod
    def fitted(
        cls,
        n_machines: int,
        procs_per_machine: int,
        degree: int,
        *,
        alpha_local: float,
        beta_local: float,
        alpha_global: float,
        beta_global: float,
        write_cost: float,
        assemble_cost: float = 0.0,
        local_name: str = "local_fit",
        global_name: str = "global_fit",
    ) -> "ClusterTopology":
        """Two-tier ``fitted_tiers`` under the historical parameter names."""
        return cls.fitted_tiers(
            (procs_per_machine, n_machines),
            degree,
            alphas=(alpha_local, alpha_global),
            betas=(beta_local, beta_global),
            write_cost=write_cost,
            assemble_cost=assemble_cost,
            names=(local_name, global_name),
        )


# Feasibility floors for fitted parameters: 1ns startup, 1 byte/ns * 1e3
# bandwidth ceiling.  Anything below these is measurement noise.
_FIT_ALPHA_FLOOR = 1e-9
_FIT_BETA_FLOOR = 1e-12


# ----------------------------------------------------------------------
# Presets
# ----------------------------------------------------------------------

def paper_smp_cluster(
    n_machines: int = 16,
    cores: int = 4,
    nics: int = 1,
) -> ClusterTopology:
    """A 2008-era cluster of SMP workstations: GigE network, shared memory.

    GigE: ~50us latency, ~125 MB/s.  Shared memory: ~1us, ~2 GB/s.
    """
    return ClusterTopology.two_tier(
        n_machines=n_machines,
        procs_per_machine=cores,
        degree=nics,
        local=LinkTier("shm", alpha=1e-6, beta=1.0 / 2.0e9),
        global_=LinkTier("gige", alpha=50e-6, beta=1.0 / 125.0e6),
        write_cost=1e-6,
        assemble_cost=2e-6,
    )


def paper_smp_3tier(
    n_machines: int = 8,
    boards: int = 2,
    cores: int = 2,
    nics: int = 1,
) -> ClusterTopology:
    """Three-tier SMP-cluster variant: shared memory within a board, a NUMA
    interconnect between a machine's boards, GigE between machines.

    The shape ``collective_bench`` models its three-tier probe sweep with
    (the fake-device mesh realizes cores x boards as the core axis).
    """
    return ClusterTopology(
        tiers=(
            LinkTier("shm", alpha=1e-6, beta=1.0 / 2.0e9),
            LinkTier("numa", alpha=3e-6, beta=1.0 / 1.2e9),
            LinkTier("gige", alpha=50e-6, beta=1.0 / 125.0e6),
        ),
        fanout=(cores, boards, n_machines),
        degree=nics,
        write_cost=1e-6,
        assemble_cost=2e-6,
    )


# Hardware constants for the roofline target (TPU v5e, per assignment):
#   197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9          # per link
V5E_DCN_BW_PER_HOST = 25e9  # per-host NIC aggregate (4 chips/host on v5e)
V5E_PCIE_BW = 32e9          # chip <-> host PCIe gen4 x16 per direction
V5E_HOSTS_PER_POD = 64
V5E_CHIPS_PER_HOST = 4
V5E_CHIPS_PER_POD = 256


def tpu_v5e_cluster(n_pods: int = 2) -> ClusterTopology:
    """Multi-pod TPU v5e collapsed to the paper's two tiers.

    machine = pod; proc = chip; degree = host NICs per pod (parallel egress).
    local tier = ICI (per-hop), global tier = DCN (per host NIC).
    """
    return ClusterTopology.two_tier(
        n_machines=n_pods,
        procs_per_machine=V5E_CHIPS_PER_POD,
        degree=V5E_HOSTS_PER_POD,
        local=LinkTier("ici", alpha=1e-6, beta=1.0 / V5E_ICI_BW),
        global_=LinkTier("dcn", alpha=10e-6, beta=1.0 / V5E_DCN_BW_PER_HOST),
        write_cost=1e-6,
        assemble_cost=1e-6,
    )


def tpu_v5e_3tier(n_pods: int = 2) -> ClusterTopology:
    """Multi-pod TPU v5e with the full three-level link hierarchy.

    tier 0 = ICI between the 4 chips sharing a host (fast, per-hop),
    tier 1 = host PCIe crossing between hosts within a pod,
    tier 2 = DCN between pods (per host NIC, ``degree`` parallel).

    This is the hierarchy the ROADMAP's model-fidelity items need: rankings
    flip per network level, and the two-tier collapse can only express two
    of the three levels.
    """
    return ClusterTopology(
        tiers=(
            LinkTier("ici", alpha=1e-6, beta=1.0 / V5E_ICI_BW),
            LinkTier("pcie", alpha=3e-6, beta=1.0 / V5E_PCIE_BW),
            LinkTier("dcn", alpha=10e-6, beta=1.0 / V5E_DCN_BW_PER_HOST),
        ),
        fanout=(V5E_CHIPS_PER_HOST, V5E_HOSTS_PER_POD, n_pods),
        degree=V5E_HOSTS_PER_POD,
        write_cost=1e-6,
        assemble_cost=1e-6,
    )


# Named presets for ``--topology`` wiring (launcher / pod-sync planner);
# every factory takes the outermost extent (machine = pod count).
TOPOLOGY_PRESETS = {
    "v5e": tpu_v5e_cluster,
    "v5e_3tier": tpu_v5e_3tier,
    "smp": lambda n: paper_smp_cluster(n_machines=n),
}


def topology_preset(name: str, n_machines: int) -> ClusterTopology:
    """Build a named preset with ``n_machines`` outermost groups (pods)."""
    try:
        factory = TOPOLOGY_PRESETS[name]
    except KeyError:
        raise ValueError(
            f"unknown topology preset {name!r} "
            f"(known: {sorted(TOPOLOGY_PRESETS)})"
        ) from None
    return factory(n_machines)
