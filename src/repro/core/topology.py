"""Cluster topology description for the multi-core communication model.

The paper's object of study is a cluster of machines, each machine holding
several processes that share memory and share the machine's external network
links.  We keep the paper's vocabulary (machine / process / degree) and map it
onto the TPU hierarchy (pod / chip / pod-egress links) via presets at the
bottom of this file.

Everything here is plain Python (no jax) so the planner can run anywhere,
including inside launcher processes before jax initializes devices.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass


@dataclass(frozen=True)
class LinkTier:
    """One tier of the two-tier network (paper Rule 2).

    alpha:  per-message startup latency, seconds.
    beta:   per-byte transfer time, seconds/byte (1 / bandwidth).
    """

    name: str
    alpha: float
    beta: float

    @property
    def bandwidth(self) -> float:
        return 1.0 / self.beta

    def transfer_time(self, nbytes: float) -> float:
        return self.alpha + nbytes * self.beta


@dataclass(frozen=True)
class ClusterTopology:
    """A homogeneous cluster of multi-core machines.

    n_machines:         number of machines (TPU: pods).
    procs_per_machine:  processes per machine (TPU: chips per pod).
    degree:             external links usable *simultaneously* by one machine
                        (paper Rule 3; TPU: host NICs per pod).
    local / global_:    link tiers (paper Rule 2).
    write_cost:         constant time for a shared-memory write visible to any
                        subset of co-located processes (paper Rule 1, "write").
    assemble_cost:      per-message assembly time charged when a process's
                        buffer must be *read* (paper Rule 1, "read").
    """

    n_machines: int
    procs_per_machine: int
    degree: int
    local: LinkTier
    global_: LinkTier
    write_cost: float
    assemble_cost: float

    def __post_init__(self) -> None:
        if self.n_machines < 1:
            raise ValueError("n_machines must be >= 1")
        if self.procs_per_machine < 1:
            raise ValueError("procs_per_machine must be >= 1")
        if not (1 <= self.degree):
            raise ValueError("degree must be >= 1")
        if self.local.alpha > self.global_.alpha or self.local.beta > self.global_.beta:
            # Rule 2: local edges are short, global edges are long.
            raise ValueError("local tier must be at least as fast as global tier")

    # ------------------------------------------------------------------
    # process <-> machine arithmetic
    # ------------------------------------------------------------------
    @property
    def n_procs(self) -> int:
        return self.n_machines * self.procs_per_machine

    def machine_of(self, proc: int) -> int:
        return proc // self.procs_per_machine

    def procs_of(self, machine: int) -> range:
        base = machine * self.procs_per_machine
        return range(base, base + self.procs_per_machine)

    def co_located(self, p: int, q: int) -> bool:
        return self.machine_of(p) == self.machine_of(q)

    def tier(self, p: int, q: int) -> LinkTier:
        return self.local if self.co_located(p, q) else self.global_

    # ------------------------------------------------------------------
    # round-based view (telephone model + the paper's three rules)
    # ------------------------------------------------------------------
    def global_round_time(self, nbytes: float) -> float:
        """Duration of one *global* round for an nbytes message.

        Paper: "we'll assume any number of internal edges may be traversed
        during a single round and include this extra cost in our round length
        estimate" -- the round length is the global transfer plus the local
        slack that hides any intra-machine pattern.
        """
        local_slack = self.write_cost + math.ceil(
            math.log2(max(self.procs_per_machine, 2))
        ) * self.local.transfer_time(nbytes)
        return self.global_.transfer_time(nbytes) + self.assemble_cost + local_slack

    def local_round_time(self, nbytes: float) -> float:
        """Duration of one *local* round (one clique edge, Rule 1 'read')."""
        return self.local.transfer_time(nbytes) + self.assemble_cost

    def with_(self, **kw) -> "ClusterTopology":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # calibration interface
    # ------------------------------------------------------------------
    def param_vector(self) -> tuple[float, float, float, float, float, float]:
        """The model's free parameters as the canonical fit vector.

        Order matches ``simulator.cost_features`` / ``comm.calibrate``:
        (local.alpha, local.beta, global.alpha, global.beta, write_cost,
        assemble_cost).
        """
        return (
            self.local.alpha,
            self.local.beta,
            self.global_.alpha,
            self.global_.beta,
            self.write_cost,
            self.assemble_cost,
        )

    @classmethod
    def fitted(
        cls,
        n_machines: int,
        procs_per_machine: int,
        degree: int,
        *,
        alpha_local: float,
        beta_local: float,
        alpha_global: float,
        beta_global: float,
        write_cost: float,
        assemble_cost: float = 0.0,
        local_name: str = "local_fit",
        global_name: str = "global_fit",
    ) -> "ClusterTopology":
        """Topology from empirically fitted parameters (``comm.calibrate``).

        Measured fits can come back degenerate (a negative intercept from
        noise, or a "local" tier that probed slower than the global one on
        hardware where both tiers share a NIC), so this constructor projects
        onto the model's feasible region instead of raising: every parameter
        is floored at a small positive epsilon and the local tier is clamped
        to be at least as fast as the global tier (Rule 2).
        """
        a_g = max(alpha_global, _FIT_ALPHA_FLOOR)
        b_g = max(beta_global, _FIT_BETA_FLOOR)
        a_l = min(max(alpha_local, _FIT_ALPHA_FLOOR), a_g)
        b_l = min(max(beta_local, _FIT_BETA_FLOOR), b_g)
        return cls(
            n_machines=n_machines,
            procs_per_machine=procs_per_machine,
            degree=degree,
            local=LinkTier(local_name, alpha=a_l, beta=b_l),
            global_=LinkTier(global_name, alpha=a_g, beta=b_g),
            write_cost=max(write_cost, _FIT_ALPHA_FLOOR),
            assemble_cost=max(assemble_cost, 0.0),
        )


# Feasibility floors for fitted parameters: 1ns startup, 1 byte/ns * 1e3
# bandwidth ceiling.  Anything below these is measurement noise.
_FIT_ALPHA_FLOOR = 1e-9
_FIT_BETA_FLOOR = 1e-12


# ----------------------------------------------------------------------
# Presets
# ----------------------------------------------------------------------

def paper_smp_cluster(
    n_machines: int = 16,
    cores: int = 4,
    nics: int = 1,
) -> ClusterTopology:
    """A 2008-era cluster of SMP workstations: GigE network, shared memory.

    GigE: ~50us latency, ~125 MB/s.  Shared memory: ~1us, ~2 GB/s.
    """
    return ClusterTopology(
        n_machines=n_machines,
        procs_per_machine=cores,
        degree=nics,
        local=LinkTier("shm", alpha=1e-6, beta=1.0 / 2.0e9),
        global_=LinkTier("gige", alpha=50e-6, beta=1.0 / 125.0e6),
        write_cost=1e-6,
        assemble_cost=2e-6,
    )


# Hardware constants for the roofline target (TPU v5e, per assignment):
#   197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9          # per link
V5E_DCN_BW_PER_HOST = 25e9  # per-host NIC aggregate (4 chips/host on v5e)
V5E_HOSTS_PER_POD = 64
V5E_CHIPS_PER_POD = 256


def tpu_v5e_cluster(n_pods: int = 2) -> ClusterTopology:
    """Multi-pod TPU v5e, the production target of this framework.

    machine = pod; proc = chip; degree = host NICs per pod (parallel egress).
    local tier = ICI (per-hop), global tier = DCN (per host NIC).
    """
    return ClusterTopology(
        n_machines=n_pods,
        procs_per_machine=V5E_CHIPS_PER_POD,
        degree=V5E_HOSTS_PER_POD,
        local=LinkTier("ici", alpha=1e-6, beta=1.0 / V5E_ICI_BW),
        global_=LinkTier("dcn", alpha=10e-6, beta=1.0 / V5E_DCN_BW_PER_HOST),
        write_cost=1e-6,
        assemble_cost=1e-6,
    )
