"""Discrete simulator for Schedules under the multi-core cluster model.

Two timing views:

* ``simulate_rounds``  -- the paper's round-based telephone view: rounds are
  globally synchronous; a round's duration is the cost of its most expensive
  op (plus a write-slack term when shared-memory publication chains onto a
  global receive, per the paper's "internal edges hide in the round length").

* ``simulate_async``   -- a LogP-style continuous view: ops start as soon as
  their data, their endpoints' ports, and a machine egress link are free.
  This is the "more realistic cost model" the paper points to as future work.

``validate`` enforces the model's structural rules:

  R0 (telephone, full-duplex single-port): per round each proc is the source
     of <=1 transfer and the destination of <=1 transfer; a LocalWrite
     occupies the writer's source port.
  R1 (read-is-not-write): LocalWrite readers must be co-located with the
     writer; readers' ports are NOT occupied (shared memory).  Local Sends
     are *reads* and do occupy ports.
  R3 (parallel egress): a machine's global transfers share its ``degree``
     external links.  Schedules designed for the model keep <= degree
     concurrent global transfers per machine per round (checked with
     ``strict_egress=True``); hierarchy-oblivious schedules may oversubscribe,
     in which case the simulators charge the ceil(usage/degree) serialization
     instead of rejecting -- this is precisely the hidden cost the paper says
     flat algorithms pay on multi-core clusters.

``check_semantics`` replays payload knowledge and asserts the collective's
postcondition (who must know what).
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass

from .schedules import LocalWrite, Schedule, Send
from .topology import ClusterTopology


class ScheduleError(ValueError):
    pass


# ----------------------------------------------------------------------
# Structural validation (the model's rules)
# ----------------------------------------------------------------------

def validate(sched: Schedule, strict_egress: bool = False) -> None:
    topo = sched.topo
    for rix, rnd in enumerate(sched.rounds):
        src_used: dict[int, int] = defaultdict(int)
        dst_used: dict[int, int] = defaultdict(int)
        mach_out: dict[int, int] = defaultdict(int)
        mach_in: dict[int, int] = defaultdict(int)
        for op in rnd.ops:
            if isinstance(op, Send):
                if op.src == op.dst:
                    raise ScheduleError(f"round {rix}: self-send at {op.src}")
                src_used[op.src] += 1
                dst_used[op.dst] += 1
                if not topo.co_located(op.src, op.dst):
                    mach_out[topo.machine_of(op.src)] += 1
                    mach_in[topo.machine_of(op.dst)] += 1
            elif isinstance(op, LocalWrite):
                src_used[op.writer] += 1
                for r in op.readers:
                    if not topo.co_located(op.writer, r):
                        raise ScheduleError(
                            f"round {rix}: LocalWrite crosses machines "
                            f"({op.writer} -> {r})"
                        )
            else:  # pragma: no cover
                raise ScheduleError(f"round {rix}: unknown op {op!r}")
        for p, n in src_used.items():
            if n > 1:
                raise ScheduleError(f"round {rix}: proc {p} sources {n} ops")
        for p, n in dst_used.items():
            if n > 1:
                raise ScheduleError(f"round {rix}: proc {p} receives {n} ops")
        if strict_egress:
            for mach, n in mach_out.items():
                if n > topo.degree:
                    raise ScheduleError(
                        f"round {rix}: machine {mach} uses {n} egress links "
                        f"(degree {topo.degree})"
                    )
            for mach, n in mach_in.items():
                if n > topo.degree:
                    raise ScheduleError(
                        f"round {rix}: machine {mach} uses {n} ingress links "
                        f"(degree {topo.degree})"
                    )


# ----------------------------------------------------------------------
# Timing
# ----------------------------------------------------------------------

def _op_cost(topo: ClusterTopology, op) -> float:
    if isinstance(op, LocalWrite):
        return topo.write_cost
    tier = topo.tier(op.src, op.dst)
    return tier.transfer_time(op.nbytes) + topo.assemble_cost


def simulate_rounds(sched: Schedule, check: bool = True) -> float:
    """Round-based (telephone) simulated completion time, seconds.

    A round's duration is its most expensive op, multiplied by the NIC
    serialization factor when a machine's global transfers oversubscribe its
    ``degree`` shared links (the paper's shared-connection rule).
    """
    if check:
        validate(sched)
    topo = sched.topo
    total = 0.0
    for rnd in sched.rounds:
        if not rnd.ops:
            continue
        dur = max(_op_cost(topo, op) for op in rnd.ops)
        mach_out: dict[int, int] = defaultdict(int)
        mach_in: dict[int, int] = defaultdict(int)
        has_global = False
        has_write = False
        for op in rnd.ops:
            if isinstance(op, Send) and not topo.co_located(op.src, op.dst):
                has_global = True
                mach_out[topo.machine_of(op.src)] += 1
                mach_in[topo.machine_of(op.dst)] += 1
            elif isinstance(op, LocalWrite):
                has_write = True
        serial = 1
        for n in list(mach_out.values()) + list(mach_in.values()):
            serial = max(serial, math.ceil(n / topo.degree))
        dur *= serial
        if has_global and has_write:
            # chained shared-memory publish hides inside the round slack
            dur += topo.write_cost
        total += dur
    return total


# ----------------------------------------------------------------------
# Linear cost decomposition (the calibration interface)
# ----------------------------------------------------------------------

N_COST_FEATURES = 6  # (alpha_l, beta_l, alpha_g, beta_g, write, assemble)


def cost_features(
    sched: Schedule, params: tuple | None = None
) -> tuple[float, float, float, float, float, float]:
    """Decompose ``simulate_rounds`` into a parameter-linear feature vector.

    Returns coefficients ``f`` such that ``dot(f, params) ==
    simulate_rounds(sched)`` where ``params`` is the topology's
    ``param_vector()`` -- (local.alpha, local.beta, global.alpha,
    global.beta, write_cost, assemble_cost).

    The round model is piecewise linear in the parameters: each round costs
    its most expensive op (times the NIC serialization factor), and *which*
    op dominates depends on the parameters.  ``params`` selects the
    linearization point (defaults to ``sched.topo``'s own values); the
    identity above is exact as long as the per-round argmax doesn't change.
    ``comm.calibrate`` iterates fit -> re-linearize until it does not.
    """
    topo = sched.topo
    if params is None:
        params = topo.param_vector()
    al, bl, ag, bg, w, asm = params

    def op_cost(op) -> float:
        if isinstance(op, LocalWrite):
            return w
        if topo.co_located(op.src, op.dst):
            return al + op.nbytes * bl + asm
        return ag + op.nbytes * bg + asm

    feats = [0.0] * N_COST_FEATURES
    for rnd in sched.rounds:
        if not rnd.ops:
            continue
        best = max(rnd.ops, key=op_cost)
        mach_out: dict[int, int] = defaultdict(int)
        mach_in: dict[int, int] = defaultdict(int)
        has_global = False
        has_write = False
        for op in rnd.ops:
            if isinstance(op, Send) and not topo.co_located(op.src, op.dst):
                has_global = True
                mach_out[topo.machine_of(op.src)] += 1
                mach_in[topo.machine_of(op.dst)] += 1
            elif isinstance(op, LocalWrite):
                has_write = True
        serial = 1
        for n in list(mach_out.values()) + list(mach_in.values()):
            serial = max(serial, math.ceil(n / topo.degree))
        row = [0.0] * N_COST_FEATURES
        if isinstance(best, LocalWrite):
            row[4] = 1.0
        elif topo.co_located(best.src, best.dst):
            row[0], row[1], row[5] = 1.0, best.nbytes, 1.0
        else:
            row[2], row[3], row[5] = 1.0, best.nbytes, 1.0
        for i in range(N_COST_FEATURES):
            feats[i] += row[i] * serial
        if has_global and has_write:
            feats[4] += 1.0
    return tuple(feats)


def affine_time(build, m1: float = 1024.0,
                m2: float = 2048.0) -> tuple[float, float]:
    """(A, B) with round-model time t(m) = A + B*m for a schedule family.

    ``build`` maps a message size to a Schedule (which carries its own
    topology); every generator's round time is exactly affine in m (each
    op's bytes is a fixed multiple of m), so two evaluations pin the whole
    curve and the predicted time for *arbitrary* m is O(1) thereafter.
    """
    s1, s2 = build(m1), build(m2)
    validate(s1)  # non-strict: flat schedules may oversubscribe NICs
    t1 = simulate_rounds(s1, check=False)
    t2 = simulate_rounds(s2, check=False)
    B = (t2 - t1) / (m2 - m1)
    return t1 - B * m1, B


def simulate_async(sched: Schedule, check: bool = True) -> float:
    """Continuous (LogP-style) simulated completion time, seconds.

    Ops are processed in schedule order; each starts when (a) every payload
    chunk it carries is known at the source, (b) the source's send port and
    destination's receive port are free, (c) for global transfers, an egress
    link of the source machine and an ingress link of the destination machine
    are free.  Chunks never seen before count as origin data (ready at t=0).
    """
    if check:
        validate(sched)
    topo = sched.topo
    P = topo.n_procs
    d = topo.degree
    src_free = [0.0] * P
    dst_free = [0.0] * P
    # per machine: d egress and d ingress links, each a next-free time
    out_links = [[0.0] * d for _ in range(topo.n_machines)]
    in_links = [[0.0] * d for _ in range(topo.n_machines)]
    known: dict[tuple[int, object], float] = {}

    def chunk_ready(proc: int, payload) -> float:
        t = 0.0
        for ch in payload:
            t = max(t, known.get((proc, ch), 0.0))
        return t

    def learn(proc: int, payload, t: float) -> None:
        for ch in payload:
            cur = known.get((proc, ch))
            if cur is None or t < cur:
                known[(proc, ch)] = t

    finish = 0.0
    for rnd in sched.rounds:
        for op in rnd.ops:
            if isinstance(op, LocalWrite):
                start = max(chunk_ready(op.writer, op.payload), src_free[op.writer])
                end = start + topo.write_cost
                src_free[op.writer] = end
                learn(op.writer, op.payload, start)
                for r in op.readers:
                    learn(r, op.payload, end)
            else:
                tier = topo.tier(op.src, op.dst)
                start = max(
                    chunk_ready(op.src, op.payload),
                    src_free[op.src],
                    dst_free[op.dst],
                )
                if tier is topo.global_:
                    mo = out_links[topo.machine_of(op.src)]
                    mi = in_links[topo.machine_of(op.dst)]
                    ko = min(range(d), key=lambda k: mo[k])
                    ki = min(range(d), key=lambda k: mi[k])
                    start = max(start, mo[ko], mi[ki])
                end = start + tier.transfer_time(op.nbytes) + topo.assemble_cost
                if tier is topo.global_:
                    mo[ko] = end
                    mi[ki] = end
                src_free[op.src] = end
                dst_free[op.dst] = end
                learn(op.dst, op.payload, end)
            finish = max(finish, end)
    return finish


# ----------------------------------------------------------------------
# Collective semantics
# ----------------------------------------------------------------------

def _replay_knowledge(sched: Schedule) -> dict[int, set]:
    know: dict[int, set] = defaultdict(set)
    # endowments
    P = sched.topo.n_procs
    if sched.collective == "broadcast":
        know[sched.root].add(("bcast", sched.root))
    elif sched.collective in ("gather", "all_gather"):
        for p in range(P):
            know[p].add(p)
    elif sched.collective == "all_reduce":
        c = sched.topo.procs_per_machine
        for p in range(P):
            for s in range(P):
                know[p].add(("rs", s, p))
            know[p].add(("ar", p))
            for s in range(c):
                know[p].add(("lrs", sched.topo.machine_of(p), s, p % c))
    elif sched.collective == "all_to_all":
        for p in range(P):
            for q in range(P):
                know[p].add(("a2a", p, q))
    for rnd in sched.rounds:
        recv: list[tuple[int, frozenset]] = []
        for op in rnd.ops:
            if isinstance(op, Send):
                recv.append((op.dst, op.payload))
            else:
                for r in op.readers:
                    recv.append((r, op.payload))
                recv.append((op.writer, op.payload))
        for dst, pay in recv:
            know[dst] |= set(pay)
    return know


def check_semantics(sched: Schedule) -> None:
    """Assert the collective's postcondition where payloads are concrete."""
    topo = sched.topo
    P = topo.n_procs
    know = _replay_knowledge(sched)
    if sched.collective == "broadcast":
        tok = ("bcast", sched.root)
        missing = [p for p in range(P) if tok not in know[p]]
        if missing:
            raise ScheduleError(f"broadcast incomplete: missing at {missing}")
    elif sched.collective == "gather":
        missing = [p for p in range(P) if p not in know[sched.root]]
        if missing:
            raise ScheduleError(f"gather incomplete: root lacks {missing}")
    elif sched.collective == "all_gather":
        for p in range(P):
            lack = [q for q in range(P) if q not in know[p]]
            if lack:
                raise ScheduleError(f"all_gather incomplete: {p} lacks {lack}")
    elif sched.collective == "all_reduce":
        _check_allreduce(sched, know)
    elif sched.collective == "all_to_all":
        _check_alltoall(sched)
    else:  # pragma: no cover
        raise ScheduleError(f"unknown collective {sched.collective}")


def _check_allreduce(sched: Schedule, know) -> None:
    topo = sched.topo
    P = topo.n_procs
    if sched.name == "allreduce_flat_ring":
        for p in range(P):
            for s in range(P):
                lack = [q for q in range(P) if ("rs", s, q) not in know[p]]
                if lack:
                    raise ScheduleError(
                        f"all_reduce: proc {p} shard {s} missing contribs {lack}"
                    )
    elif sched.name == "allreduce_hier_par_bw":
        # Phase-1 local reduce-scatter completeness (real payloads), plus
        # inter-machine volume lower bound for the synthetic phases.
        M, c, m = topo.n_machines, topo.procs_per_machine, sched.nbytes
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            for i, p in enumerate(procs):
                shard = (i + 1) % c
                lack = [
                    j
                    for j in range(c)
                    if ("lrs", mach, shard, j) not in know[p]
                ]
                if lack:
                    raise ScheduleError(
                        f"all_reduce bw: machine {mach} proc {p} shard {shard} "
                        f"missing local contribs {lack}"
                    )
        if M > 1:
            gbytes = sched.total_global_bytes()
            need = M * 2 * m * (M - 1) / M * 0.999
            if gbytes < need:
                raise ScheduleError(
                    f"all_reduce bw: global bytes {gbytes} < required {need}"
                )
    else:
        # hierarchical: check (a) local reduce completeness via real payloads,
        # (b) inter-machine byte volume >= ring-optimal 2*m*(M-1)/M per
        # machine boundary pair, (c) every proc touched by a final publish.
        M, c, m = topo.n_machines, topo.procs_per_machine, sched.nbytes
        for mach in range(M):
            head = next(iter(topo.procs_of(mach)))
            lack = [q for q in topo.procs_of(mach) if ("ar", q) not in know[head]]
            if lack:
                raise ScheduleError(
                    f"all_reduce: machine {mach} local reduce missing {lack}"
                )
        if M > 1:
            gbytes = sched.total_global_bytes()
            need = M * 2 * m * (M - 1) / M * 0.999  # all machines, RS+AG
            if gbytes < need:
                raise ScheduleError(
                    f"all_reduce: global bytes {gbytes} < required {need}"
                )


def _check_alltoall(sched: Schedule) -> None:
    topo = sched.topo
    m = sched.nbytes
    M, c = topo.n_machines, topo.procs_per_machine
    if sched.name == "alltoall_flat_pairwise":
        know = _replay_knowledge(sched)
        P = topo.n_procs
        for q in range(P):
            lack = [p for p in range(P) if p != q and ("a2a", p, q) not in know[q]]
            if lack:
                raise ScheduleError(f"all_to_all: {q} missing from {lack}")
    else:
        # volume check: every ordered machine pair must move c*c*m bytes
        pair_bytes: dict[tuple[int, int], float] = defaultdict(float)
        for op in sched.all_ops():
            if isinstance(op, Send) and not topo.co_located(op.src, op.dst):
                key = (topo.machine_of(op.src), topo.machine_of(op.dst))
                pair_bytes[key] += op.nbytes
        for i in range(M):
            for j in range(M):
                if i == j:
                    continue
                if pair_bytes[(i, j)] < c * c * m * 0.999:
                    raise ScheduleError(
                        f"all_to_all: machines {i}->{j} moved "
                        f"{pair_bytes[(i, j)]} < {c * c * m}"
                    )


@dataclass(frozen=True)
class SimResult:
    name: str
    collective: str
    t_rounds: float
    t_async: float
    n_rounds: int
    global_bytes: float
    local_bytes: float


def evaluate(sched: Schedule) -> SimResult:
    """Validate, semantics-check, and time a schedule under both views."""
    validate(sched)
    check_semantics(sched)
    return SimResult(
        name=sched.name,
        collective=sched.collective,
        t_rounds=simulate_rounds(sched, check=False),
        t_async=simulate_async(sched, check=False),
        n_rounds=sched.n_rounds,
        global_bytes=sched.total_global_bytes(),
        local_bytes=sched.total_local_bytes(),
    )
