"""Discrete simulator for Schedules under the multi-core cluster model.

Two timing views:

* ``simulate_rounds``  -- the paper's round-based telephone view: rounds are
  globally synchronous; a round's duration is the cost of its most expensive
  op (plus a write-slack term when shared-memory publication chains onto a
  global receive, per the paper's "internal edges hide in the round length").

* ``simulate_async``   -- a LogP-style continuous view: ops start as soon as
  their data, their endpoints' ports, and a machine egress link are free.
  This is the "more realistic cost model" the paper points to as future work.

``validate`` enforces the model's structural rules:

  R0 (telephone, full-duplex single-port): per round each proc is the source
     of <=1 transfer and the destination of <=1 transfer; a LocalWrite
     occupies the writer's source port.
  R1 (read-is-not-write): LocalWrite readers must be co-located with the
     writer; readers' ports are NOT occupied (shared memory).  Local Sends
     are *reads* and do occupy ports.
  R3 (parallel egress): a machine's global transfers share its ``degree``
     external links.  Schedules designed for the model keep <= degree
     concurrent global transfers per machine per round (checked with
     ``strict_egress=True``); hierarchy-oblivious schedules may oversubscribe,
     in which case the simulators charge the ceil(usage/degree) serialization
     instead of rejecting -- this is precisely the hidden cost the paper says
     flat algorithms pay on multi-core clusters.

``check_semantics`` replays payload knowledge and asserts the collective's
postcondition (who must know what).
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass

from .schedules import LocalWrite, Schedule, Send
from .topology import ClusterTopology


class ScheduleError(ValueError):
    pass


# ----------------------------------------------------------------------
# Structural validation (the model's rules)
# ----------------------------------------------------------------------

def validate(sched: Schedule, strict_egress: bool = False) -> None:
    topo = sched.topo
    for rix, rnd in enumerate(sched.rounds):
        src_used: dict[int, int] = defaultdict(int)
        dst_used: dict[int, int] = defaultdict(int)
        # Rule 3, per tier: (level, group, direction) -> concurrent link use.
        # Only tiers with a finite ``degrees[level]`` are guarded; with the
        # default degrees vector that is exactly the outermost (machine)
        # boundary of the classic model.
        tier_out: dict[tuple[int, int], int] = defaultdict(int)
        tier_in: dict[tuple[int, int], int] = defaultdict(int)
        for op in rnd.ops:
            if isinstance(op, Send):
                if op.src == op.dst:
                    raise ScheduleError(f"round {rix}: self-send at {op.src}")
                src_used[op.src] += 1
                dst_used[op.dst] += 1
                t = topo.tier_index(op.src, op.dst)
                if topo.tier_degree(t):
                    tier_out[(t, topo.group_of(op.src, t))] += 1
                    tier_in[(t, topo.group_of(op.dst, t))] += 1
            elif isinstance(op, LocalWrite):
                src_used[op.writer] += 1
                for r in op.readers:
                    if topo.inner_group_of(op.writer) != topo.inner_group_of(r):
                        # Rule 1 is a *shared-memory* write: it reaches the
                        # writer's tier-0 group only (for a two-tier cluster
                        # that group is the whole machine).
                        raise ScheduleError(
                            f"round {rix}: LocalWrite crosses shared-memory "
                            f"groups ({op.writer} -> {r})"
                        )
            else:  # pragma: no cover
                raise ScheduleError(f"round {rix}: unknown op {op!r}")
        for p, n in src_used.items():
            if n > 1:
                raise ScheduleError(f"round {rix}: proc {p} sources {n} ops")
        for p, n in dst_used.items():
            if n > 1:
                raise ScheduleError(f"round {rix}: proc {p} receives {n} ops")
        if strict_egress:
            for (t, g), n in tier_out.items():
                if n > topo.tier_degree(t):
                    raise ScheduleError(
                        f"round {rix}: tier-{t} group {g} uses {n} egress "
                        f"links (degree {topo.tier_degree(t)})"
                    )
            for (t, g), n in tier_in.items():
                if n > topo.tier_degree(t):
                    raise ScheduleError(
                        f"round {rix}: tier-{t} group {g} uses {n} ingress "
                        f"links (degree {topo.tier_degree(t)})"
                    )


# ----------------------------------------------------------------------
# Timing
# ----------------------------------------------------------------------

def _op_cost(topo: ClusterTopology, op) -> float:
    if isinstance(op, LocalWrite):
        return topo.write_cost
    tier = topo.tier(op.src, op.dst)
    return tier.transfer_time(op.nbytes) + topo.assemble_cost


def _round_shape(topo: ClusterTopology, rnd: Round) -> tuple[int, bool, bool]:
    """(link serialization factor, has_global, has_write) for one round.

    The serialization factor generalizes the paper's shared-NIC rule per
    tier: a level-``l`` group's tier-``l`` transfers share its
    ``degrees[l]`` links (0 = unlimited).  With the default degrees vector
    only the outermost (machine) boundary is guarded -- the classic Rule 3.
    """
    tier_out: dict[tuple[int, int], int] = defaultdict(int)
    tier_in: dict[tuple[int, int], int] = defaultdict(int)
    has_global = False
    has_write = False
    for op in rnd.ops:
        if isinstance(op, Send):
            t = topo.tier_index(op.src, op.dst)
            if t == topo.n_tiers - 1:
                has_global = True
            if topo.tier_degree(t):
                tier_out[(t, topo.group_of(op.src, t))] += 1
                tier_in[(t, topo.group_of(op.dst, t))] += 1
        elif isinstance(op, LocalWrite):
            has_write = True
    serial = 1
    for (t, _), n in list(tier_out.items()) + list(tier_in.items()):
        serial = max(serial, math.ceil(n / topo.tier_degree(t)))
    return serial, has_global, has_write


def _round_time(topo: ClusterTopology, rnd: Round) -> float:
    """One round's duration: most expensive op times the NIC serialization
    factor, plus the chained write slack (see ``simulate_rounds``)."""
    if not rnd.ops:
        return 0.0
    serial, has_global, has_write = _round_shape(topo, rnd)
    dur = max(_op_cost(topo, op) for op in rnd.ops) * serial
    if has_global and has_write:
        # chained shared-memory publish hides inside the round slack
        dur += topo.write_cost
    return dur


def simulate_rounds(sched: Schedule, check: bool = True) -> float:
    """Round-based (telephone) simulated completion time, seconds.

    A round's duration is its most expensive op, multiplied by the NIC
    serialization factor when a machine's global transfers oversubscribe its
    ``degree`` shared links (the paper's shared-connection rule).
    """
    if check:
        validate(sched)
    topo = sched.topo
    return sum(_round_time(topo, rnd) for rnd in sched.rounds)


# Canonical alias: "simulate a schedule" without qualification means the
# exact round model (what calibration fits and what ``repro.sim`` replays).
simulate = simulate_rounds


# ----------------------------------------------------------------------
# Pipelined (bucketed) cost view
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class PipelinedCost:
    """Modelled time for a schedule run as ``n_chunks`` pipelined chunks.

    t_chunk:       one chunk through every stage (the chunk latency).
    t_serial:      ``n_chunks * t_chunk`` -- bucketed but UNpipelined (each
                   chunk waits for the previous one to fully finish).
    t_pipelined:   the overlapped time: while chunk k's global exchange is
                   on the wire, chunk k+1's local combine proceeds.
    stages:        per-stage ('local' | 'global', seconds) breakdown of one
                   chunk.
    """

    n_chunks: int
    chunk_bytes: float
    t_chunk: float
    t_serial: float
    t_pipelined: float
    stages: tuple

    @property
    def speedup_vs_serial(self) -> float:
        return self.t_serial / self.t_pipelined if self.t_pipelined else 1.0


def pipeline_stages(sched: Schedule) -> list[tuple[str, float]]:
    """Contiguous same-tier runs of rounds, as ('local'|'global', seconds).

    A round is 'global' when it carries any cross-machine Send, else
    'local' (clique reads and shared-memory writes).  Consecutive rounds on
    the same tier merge into one pipeline stage: the tiers are distinct
    resources (Rule 2), so a chunk's stages must run in order but chunk
    k+1 may occupy a stage as soon as chunk k has vacated it.
    """
    topo = sched.topo
    stages: list[tuple[str, float]] = []
    for rnd in sched.rounds:
        if not rnd.ops:
            continue
        _, has_global, _ = _round_shape(topo, rnd)
        kind = "global" if has_global else "local"
        dur = _round_time(topo, rnd)
        if stages and stages[-1][0] == kind:
            stages[-1] = (kind, stages[-1][1] + dur)
        else:
            stages.append((kind, dur))
    return stages


def simulate_pipelined(build, m: float, n_chunks: int,
                       check: bool = True) -> PipelinedCost:
    """Price a bucketed, pipelined schedule family (the paper's Rule-3
    concurrency between tiers, made costable).

    The m-byte message is split into ``n_chunks`` equal chunks; each chunk
    runs the schedule ``build(m / n_chunks)``.  Maximal runs of same-tier
    rounds form pipeline stages (``pipeline_stages``); chunk k+1 enters
    stage s as soon as chunk k has released it AND chunk k+1 cleared stage
    s-1 -- so round k's local combine overlaps round k+1's global send.
    Linear-pipeline bound:

        T = sum_s t_s + (n_chunks - 1) * max_s t_s

    which is strictly below the serial ``n_chunks * sum_s t_s`` whenever
    more than one stage has nonzero duration (i.e. there is local work to
    hide under the global exchange).
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    chunk_m = m / n_chunks
    sched = build(chunk_m)
    if check:
        validate(sched)
    stages = pipeline_stages(sched)
    t_chunk = sum(t for _, t in stages)
    bottleneck = max((t for _, t in stages), default=0.0)
    return PipelinedCost(
        n_chunks=n_chunks,
        chunk_bytes=chunk_m,
        t_chunk=t_chunk,
        t_serial=n_chunks * t_chunk,
        t_pipelined=t_chunk + (n_chunks - 1) * bottleneck,
        stages=tuple(stages),
    )


# ----------------------------------------------------------------------
# Compute-overlapped (backward-shadow) cost view
# ----------------------------------------------------------------------

# Per-issue dispatch overhead charged on the compute path for every bucket
# launched during an overlapped sync (host-side enqueue of an interleaved
# collective).  This constant is the LAST-RESORT fallback only: overlap
# pricing resolves the cost through ``comm.grad_sync.resolve_dispatch_cost``,
# which prefers calibration meta ("dispatch_cost"), then the committed
# BENCH_step.json fixture's ``dispatch_cost_fit_us`` (refreshed by each
# bench run via ``fit_dispatch_cost`` against the dispatch-free model).
# With neither available -- installed package, fresh clone -- assume zero
# overhead rather than invent one.
DEFAULT_DISPATCH_COST = 0.0


def fit_dispatch_cost(t_measured: float, t_modelled: float,
                      n_issues: int) -> float:
    """Per-issue dispatch cost explaining a measured overlapped step.

    Attributes the whole measured-minus-modelled gap of an overlapped step
    to its ``n_issues`` bucket dispatches, floored at zero (a step faster
    than the model fits no overhead).  One-point fit by design: it is
    refreshed from each BENCH_step run and stored in calibration meta.
    """
    if n_issues < 1:
        raise ValueError(f"n_issues must be >= 1, got {n_issues}")
    return max(0.0, (t_measured - t_modelled) / n_issues)


@dataclass(frozen=True)
class OverlappedCost:
    """Modelled time for a bucketed sync overlapped with backward compute.

    The gradient's ``n_chunks`` buckets are laid out in reverse layer order,
    so backward releases bucket k at ``(k + 1) * compute_time / n_chunks``
    (the last layers' gradients come first); each released bucket runs the
    pipelined comm stages.  Only the comm that escapes the compute shadow is
    charged on top of ``compute_time``.

    compute_time:  the backward/accumulation window shadowing the sync.
    dispatch_cost: per-issue dispatch overhead; each of the ``n_chunks``
                   interleaved bucket launches stretches the compute path
                   by this much (the serial baseline issues no interleaved
                   buckets and pays none).
    t_chunk:       one bucket through every comm stage.
    t_comm:        the pipelined comm-only time (``simulate_pipelined``'s
                   bound for the same chunking; what a post-backward sync
                   would take).
    t_serial:      ``compute_time + t_comm`` -- backward, then sync.
    t_overlapped:  completion with the sync riding the backward shadow.
    t_exposed:     ``t_overlapped - compute_time``: comm left on the
                   critical path.
    """

    n_chunks: int
    chunk_bytes: float
    compute_time: float
    t_chunk: float
    t_comm: float
    t_serial: float
    t_overlapped: float
    stages: tuple
    dispatch_cost: float = 0.0

    @property
    def t_exposed(self) -> float:
        return self.t_overlapped - self.compute_time

    @property
    def speedup_vs_serial(self) -> float:
        return self.t_serial / self.t_overlapped if self.t_overlapped else 1.0


def simulate_overlapped(build, m: float, n_chunks: int, compute_time: float,
                        check: bool = True,
                        dispatch_cost: float = DEFAULT_DISPATCH_COST,
                        ) -> OverlappedCost:
    """Price a bucketed sync whose buckets are released by backward compute.

    Extends ``simulate_pipelined`` with a compute-overlap term: the m-byte
    gradient is cut into ``n_chunks`` buckets (reverse layer order), bucket
    k becoming available at ``r_k = (k + 1) * compute_time / n_chunks``
    while earlier buckets' comm is already in flight.  With per-chunk stage
    times t_s (bottleneck b = max_s t_s) this is a flow shop of identical
    jobs with release dates, whose exact completion is

        T = sum_s t_s + max(compute_time,
                            compute_time / n_chunks + (n_chunks - 1) * b)

    (the max runs over which bucket's release anchors the critical path:
    the last bucket when compute dominates, the first when comm does).
    ``compute_time = 0`` degenerates to ``simulate_pipelined`` exactly, and
    for ``compute_time > 0, n_chunks > 1, dispatch_cost = 0`` the bound is
    strictly below the serial ``compute_time + t_pipelined``: overlapping
    must pay off.

    ``dispatch_cost`` models the per-issue overhead of launching a bucket's
    collective mid-backward: every one of the ``n_chunks`` issues stretches
    the compute shadow (and delays every release) by that much, so the
    effective shadow is ``compute_time + n_chunks * dispatch_cost``.  The
    serial baseline (backward, then one sync) issues nothing mid-compute
    and keeps ``t_serial`` unchanged -- with a positive dispatch cost,
    overlapping can now LOSE to serial, which is exactly the measured
    behaviour the term exists to price.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    if compute_time < 0:
        raise ValueError(f"compute_time must be >= 0, got {compute_time}")
    if dispatch_cost < 0:
        raise ValueError(f"dispatch_cost must be >= 0, got {dispatch_cost}")
    chunk_m = m / n_chunks
    sched = build(chunk_m)
    if check:
        validate(sched)
    stages = pipeline_stages(sched)
    t_chunk = sum(t for _, t in stages)
    bottleneck = max((t for _, t in stages), default=0.0)
    t_comm = t_chunk + (n_chunks - 1) * bottleneck
    shadow = compute_time + n_chunks * dispatch_cost
    t_over = t_chunk + max(
        shadow, shadow / n_chunks + (n_chunks - 1) * bottleneck
    )
    return OverlappedCost(
        n_chunks=n_chunks,
        chunk_bytes=chunk_m,
        compute_time=compute_time,
        t_chunk=t_chunk,
        t_comm=t_comm,
        t_serial=compute_time + t_comm,
        t_overlapped=t_over,
        stages=tuple(stages),
        dispatch_cost=dispatch_cost,
    )


# ----------------------------------------------------------------------
# Linear cost decomposition (the calibration interface)
# ----------------------------------------------------------------------

# Feature width of the historical two-tier vector
# (alpha_l, beta_l, alpha_g, beta_g, write, assemble); N-tier topologies
# carry 2 * n_tiers + 2 features -- see ``n_cost_features``.
N_COST_FEATURES = 6


def n_cost_features(topo: ClusterTopology) -> int:
    """Width of the ``cost_features`` vector for one topology: per-tier
    (alpha, beta) columns plus (write_cost, assemble_cost)."""
    return 2 * topo.n_tiers + 2


def cost_features(sched: Schedule, params: tuple | None = None) -> tuple:
    """Decompose ``simulate_rounds`` into a parameter-linear feature vector.

    Returns coefficients ``f`` such that ``dot(f, params) ==
    simulate_rounds(sched)`` where ``params`` is the topology's
    ``param_vector()`` -- (alpha_0, beta_0, ..., alpha_{T-1}, beta_{T-1},
    write_cost, assemble_cost), one (alpha, beta) column pair per tier,
    innermost first (for a two-tier topology: local then global).

    The round model is piecewise linear in the parameters: each round costs
    its most expensive op (times the NIC serialization factor), and *which*
    op dominates depends on the parameters.  ``params`` selects the
    linearization point (defaults to ``sched.topo``'s own values); the
    identity above is exact as long as the per-round argmax doesn't change.
    ``comm.calibrate`` iterates fit -> re-linearize until it does not.
    """
    topo = sched.topo
    if params is None:
        params = topo.param_vector()
    width = n_cost_features(topo)
    feats = [0.0] * width
    for rnd in sched.rounds:
        row = _round_feature_row(topo, rnd, params)
        for i in range(width):
            feats[i] += row[i]
    return tuple(feats)


def _round_feature_row(topo: ClusterTopology, rnd: Round, params) -> list:
    """One round's contribution to the ``cost_features`` vector, such that
    ``dot(row, params) == _round_time`` at the linearization point."""
    width = n_cost_features(topo)
    if not rnd.ops:
        return [0.0] * width
    w_ix, asm_ix = width - 2, width - 1

    def op_cost(op) -> float:
        if isinstance(op, LocalWrite):
            return params[w_ix]
        t = topo.tier_index(op.src, op.dst)
        return params[2 * t] + op.nbytes * params[2 * t + 1] + params[asm_ix]

    best = max(rnd.ops, key=op_cost)
    serial, has_global, has_write = _round_shape(topo, rnd)
    row = [0.0] * width
    if isinstance(best, LocalWrite):
        row[w_ix] = 1.0
    else:
        t = topo.tier_index(best.src, best.dst)
        row[2 * t], row[2 * t + 1], row[asm_ix] = 1.0, best.nbytes, 1.0
    row = [x * serial for x in row]
    if has_global and has_write:
        row[w_ix] += 1.0
    return row


def pipelined_cost_features(
    build, m: float, n_chunks: int, params: tuple | None = None
) -> tuple:
    """``cost_features`` analogue for ``simulate_pipelined``.

    Returns f with ``dot(f, params) == simulate_pipelined(...).t_pipelined``
    at the linearization point ``params`` (the schedule topology's own
    parameters by default): the sum of every stage's features plus
    (n_chunks - 1) copies of the bottleneck stage's -- piecewise linear in
    the parameters exactly like the round model, so calibration's
    Gauss-Newton re-linearization applies to pipelined schedules unchanged.
    """
    sched = build(m / n_chunks)
    if params is None:
        params = sched.topo.param_vector()
    feats, _, bottleneck_row, _ = _stage_row_summary(sched, params)
    if bottleneck_row is not None:
        for i in range(len(feats)):
            feats[i] += (n_chunks - 1) * bottleneck_row[i]
    return tuple(feats)


def _stage_row_summary(sched: Schedule, params):
    """(sum-of-stage-rows, t_chunk, bottleneck_row, bottleneck_t) for one
    chunk schedule, with stages grouped exactly like ``pipeline_stages`` and
    each row a ``cost_features``-style vector at the linearization point."""
    topo = sched.topo
    width = n_cost_features(topo)
    stage_rows: list[tuple[str, list]] = []
    for rnd in sched.rounds:
        if not rnd.ops:
            continue
        _, has_global, _ = _round_shape(topo, rnd)
        kind = "global" if has_global else "local"
        row = _round_feature_row(topo, rnd, params)
        if stage_rows and stage_rows[-1][0] == kind:
            prev = stage_rows[-1][1]
            stage_rows[-1] = (kind, [a + b for a, b in zip(prev, row)])
        else:
            stage_rows.append((kind, row))
    feats = [0.0] * width
    t_chunk = 0.0
    bottleneck_row, bottleneck_t = None, -1.0
    for _, row in stage_rows:
        t = sum(f * p for f, p in zip(row, params))
        t_chunk += t
        if t > bottleneck_t:
            bottleneck_row, bottleneck_t = row, t
        for i in range(width):
            feats[i] += row[i]
    return feats, t_chunk, bottleneck_row, bottleneck_t


def overlapped_cost_features(
    build, m: float, n_chunks: int, compute_time: float,
    params: tuple | None = None,
    dispatch_cost: float = DEFAULT_DISPATCH_COST,
) -> tuple:
    """``cost_features`` analogue for ``simulate_overlapped``.

    Returns ``(f, c0)`` with ``dot(f, params) + c0 ==
    simulate_overlapped(...).t_overlapped`` at the linearization point:
    ``compute_time`` and ``dispatch_cost`` are *measured* constants, not
    fitted parameters, so the whole compute shadow (``compute_time +
    n_chunks * dispatch_cost``) lands in the affine offset ``c0`` while the
    comm term stays exactly parameter-linear -- which branch of the overlap
    max dominates is chosen at the linearization point, mirroring the round
    model's argmax.  Calibration's Gauss-Newton re-linearization therefore
    applies to overlapped schedules unchanged.
    """
    if n_chunks < 1:
        raise ValueError(f"n_chunks must be >= 1, got {n_chunks}")
    sched = build(m / n_chunks)
    if params is None:
        params = sched.topo.param_vector()
    feats, _, bottleneck_row, bottleneck_t = _stage_row_summary(sched, params)
    width = len(feats)
    b = max(bottleneck_t, 0.0)
    shadow = compute_time + n_chunks * dispatch_cost
    if shadow >= shadow / n_chunks + (n_chunks - 1) * b:
        return tuple(feats), shadow
    for i in range(width):
        feats[i] += (n_chunks - 1) * bottleneck_row[i]
    return tuple(feats), shadow / n_chunks


def affine_time(build, m1: float = 1024.0,
                m2: float = 2048.0) -> tuple[float, float]:
    """(A, B) with round-model time t(m) = A + B*m for a schedule family.

    ``build`` maps a message size to a Schedule (which carries its own
    topology); every generator's round time is exactly affine in m (each
    op's bytes is a fixed multiple of m), so two evaluations pin the whole
    curve and the predicted time for *arbitrary* m is O(1) thereafter.
    """
    s1, s2 = build(m1), build(m2)
    validate(s1)  # non-strict: flat schedules may oversubscribe NICs
    t1 = simulate_rounds(s1, check=False)
    t2 = simulate_rounds(s2, check=False)
    B = (t2 - t1) / (m2 - m1)
    return t1 - B * m1, B


def simulate_async(sched: Schedule, check: bool = True) -> float:
    """Continuous (LogP-style) simulated completion time, seconds.

    Ops are processed in schedule order; each starts when (a) every payload
    chunk it carries is known at the source, (b) the source's send port and
    destination's receive port are free, (c) for global transfers, an egress
    link of the source machine and an ingress link of the destination machine
    are free.  Chunks never seen before count as origin data (ready at t=0).
    """
    if check:
        validate(sched)
    topo = sched.topo
    P = topo.n_procs
    src_free = [0.0] * P
    dst_free = [0.0] * P
    # Rule-3 link pools, per (tier, group, direction): ``degrees[l]`` links,
    # each a next-free time.  Tiers with degree 0 (unlimited) have no pool;
    # by default that leaves exactly the classic per-machine NIC pools.
    out_links: dict[tuple[int, int], list] = {}
    in_links: dict[tuple[int, int], list] = {}
    known: dict[tuple[int, object], float] = {}

    def chunk_ready(proc: int, payload) -> float:
        t = 0.0
        for ch in payload:
            t = max(t, known.get((proc, ch), 0.0))
        return t

    def learn(proc: int, payload, t: float) -> None:
        for ch in payload:
            cur = known.get((proc, ch))
            if cur is None or t < cur:
                known[(proc, ch)] = t

    finish = 0.0
    for rnd in sched.rounds:
        for op in rnd.ops:
            if isinstance(op, LocalWrite):
                start = max(chunk_ready(op.writer, op.payload), src_free[op.writer])
                end = start + topo.write_cost
                src_free[op.writer] = end
                learn(op.writer, op.payload, start)
                for r in op.readers:
                    learn(r, op.payload, end)
            else:
                tix = topo.tier_index(op.src, op.dst)
                tier = topo.tiers[tix]
                # tiers with a finite per-group link count are guarded by
                # their shared egress/ingress pools (Rule 3, per tier; by
                # default only the outermost machine boundary is finite)
                d = topo.tier_degree(tix)
                start = max(
                    chunk_ready(op.src, op.payload),
                    src_free[op.src],
                    dst_free[op.dst],
                )
                if d:
                    mo = out_links.setdefault(
                        (tix, topo.group_of(op.src, tix)), [0.0] * d
                    )
                    mi = in_links.setdefault(
                        (tix, topo.group_of(op.dst, tix)), [0.0] * d
                    )
                    ko = min(range(d), key=lambda k: mo[k])
                    ki = min(range(d), key=lambda k: mi[k])
                    start = max(start, mo[ko], mi[ki])
                end = start + tier.transfer_time(op.nbytes) + topo.assemble_cost
                if d:
                    mo[ko] = end
                    mi[ki] = end
                src_free[op.src] = end
                dst_free[op.dst] = end
                learn(op.dst, op.payload, end)
            finish = max(finish, end)
    return finish


# ----------------------------------------------------------------------
# Collective semantics
# ----------------------------------------------------------------------

def _replay_knowledge(sched: Schedule) -> dict[int, set]:
    know: dict[int, set] = defaultdict(set)
    # endowments
    P = sched.topo.n_procs
    if sched.collective == "broadcast":
        know[sched.root].add(("bcast", sched.root))
    elif sched.collective in ("gather", "all_gather"):
        for p in range(P):
            know[p].add(p)
    elif sched.collective in ("all_reduce", "reduce_scatter"):
        # "lrs" tokens live on the tier-0 (shared-memory) groups: the
        # innermost ring reduce-scatter of the hierarchical strategies (for
        # a two-tier cluster the tier-0 group is the whole machine).
        c0 = sched.topo.fanout[0]
        for p in range(P):
            for s in range(P):
                know[p].add(("rs", s, p))
            know[p].add(("ar", p))
            for s in range(c0):
                know[p].add(("lrs", sched.topo.inner_group_of(p), s, p % c0))
    elif sched.collective == "all_to_all":
        for p in range(P):
            for q in range(P):
                know[p].add(("a2a", p, q))
    for rnd in sched.rounds:
        recv: list[tuple[int, frozenset]] = []
        for op in rnd.ops:
            if isinstance(op, Send):
                recv.append((op.dst, op.payload))
            else:
                for r in op.readers:
                    recv.append((r, op.payload))
                recv.append((op.writer, op.payload))
        for dst, pay in recv:
            know[dst] |= set(pay)
    return know


def check_semantics(sched: Schedule) -> None:
    """Assert the collective's postcondition where payloads are concrete."""
    topo = sched.topo
    P = topo.n_procs
    know = _replay_knowledge(sched)
    if sched.collective == "broadcast":
        tok = ("bcast", sched.root)
        missing = [p for p in range(P) if tok not in know[p]]
        if missing:
            raise ScheduleError(f"broadcast incomplete: missing at {missing}")
    elif sched.collective == "gather":
        missing = [p for p in range(P) if p not in know[sched.root]]
        if missing:
            raise ScheduleError(f"gather incomplete: root lacks {missing}")
    elif sched.collective == "all_gather":
        for p in range(P):
            lack = [q for q in range(P) if q not in know[p]]
            if lack:
                raise ScheduleError(f"all_gather incomplete: {p} lacks {lack}")
    elif sched.collective == "all_reduce":
        _check_allreduce(sched, know)
    elif sched.collective == "reduce_scatter":
        _check_reduce_scatter(sched, know)
    elif sched.collective == "all_to_all":
        _check_alltoall(sched)
    else:  # pragma: no cover
        raise ScheduleError(f"unknown collective {sched.collective}")


def _tier_send_bytes(sched: Schedule) -> list:
    """Total Send bytes crossing each tier boundary, indexed by tier level."""
    by = [0.0] * sched.topo.n_tiers
    for op in sched.all_ops():
        if isinstance(op, Send):
            by[sched.topo.tier_index(op.src, op.dst)] += op.nbytes
    return by


def _check_tier_volumes(
    sched: Schedule, what: str, factor: float, outer_factor: float
) -> None:
    """Per-tier bandwidth lower bounds for reduction collectives.

    At tier ``l`` every level-(l+1) group must move at least
    ``factor * m * (fanout[l] - 1)`` bytes across its level-``l`` subgroup
    boundaries (each subgroup can compress its members' contributions into
    one partially-reduced m-byte vector, but combining f subgroups still
    needs f - 1 vector crossings; reduce-scatter-style exchanges meet the
    same total).  ``outer_factor`` applies at the outermost tier (2 for a
    full all-reduce: the reduced result must also fan back in).  Tier 0 is
    covered separately by the payload-level ``_check_local_rs_phase``.
    """
    topo = sched.topo
    m = sched.nbytes
    by = _tier_send_bytes(sched)
    for level in range(1, topo.n_tiers):
        f = topo.fanout[level]
        if f <= 1:
            continue
        groups = topo.n_procs // topo.group_size(level + 1)
        fac = outer_factor if level == topo.n_tiers - 1 else factor
        need = groups * fac * m * (f - 1) * 0.999
        if by[level] < need:
            raise ScheduleError(
                f"{what}: tier-{level} bytes {by[level]} < required {need}"
            )


def _check_local_rs_phase(sched: Schedule, know, what: str) -> None:
    """Phase-1 completeness of the innermost (tier-0) ring reduce-scatter:
    within every shared-memory group, proc at ring position i must have
    gathered every group member's contribution to shard (i+1) % c0."""
    topo = sched.topo
    c0 = topo.fanout[0]
    for g in range(topo.n_procs // c0):
        procs = list(topo.group_procs(1, g))
        for i, p in enumerate(procs):
            shard = (i + 1) % c0
            lack = [
                j for j in range(c0) if ("lrs", g, shard, j) not in know[p]
            ]
            if lack:
                raise ScheduleError(
                    f"{what}: group {g} proc {p} shard {shard} missing "
                    f"local contribs {lack}"
                )


def _check_reduce_scatter(sched: Schedule, know) -> None:
    """Each proc must fully reduce its designated 1/P shard; hierarchical
    variants must additionally move the bandwidth-optimal m*(M-1)/M global
    bytes per machine (half an all-reduce)."""
    topo = sched.topo
    P = topo.n_procs
    M, m = topo.n_machines, sched.nbytes
    if sched.name == "reducescatter_flat_ring":
        for p in range(P):
            shard = (p + 1) % P
            lack = [q for q in range(P) if ("rs", shard, q) not in know[p]]
            if lack:
                raise ScheduleError(
                    f"reduce_scatter: proc {p} shard {shard} missing "
                    f"contribs {lack}"
                )
    else:
        # Phase-1 local reduce-scatter completeness via real payloads ...
        _check_local_rs_phase(sched, know, "reduce_scatter")
        # ... plus the per-tier volume lower bounds for the outer phases
        # (every boundary, not just the machine seam).
        _check_tier_volumes(sched, "reduce_scatter", 1.0, 1.0)


def _check_allreduce(sched: Schedule, know) -> None:
    topo = sched.topo
    P = topo.n_procs
    if sched.name == "allreduce_flat_ring":
        for p in range(P):
            for s in range(P):
                lack = [q for q in range(P) if ("rs", s, q) not in know[p]]
                if lack:
                    raise ScheduleError(
                        f"all_reduce: proc {p} shard {s} missing contribs {lack}"
                    )
    elif sched.name == "allreduce_hier_par_bw":
        # Phase-1 local reduce-scatter completeness (real payloads), plus
        # per-tier volume lower bounds for the synthetic phases -- the
        # tier-recursive RS+AG must move 2m(f-1) per group at EVERY tier.
        _check_local_rs_phase(sched, know, "all_reduce bw")
        _check_tier_volumes(sched, "all_reduce bw", 2.0, 2.0)
    else:
        # hierarchical: check (a) local reduce completeness via real payloads,
        # (b) per-tier byte volume bounds -- ring-optimal 2*m*(M-1)/M per
        # machine at the outermost boundary, one m-byte vector crossing per
        # subgroup merge at the mid tiers -- (c) every proc touched by a
        # final publish.
        M = topo.n_machines
        for mach in range(M):
            head = next(iter(topo.procs_of(mach)))
            lack = [q for q in topo.procs_of(mach) if ("ar", q) not in know[head]]
            if lack:
                raise ScheduleError(
                    f"all_reduce: machine {mach} local reduce missing {lack}"
                )
        _check_tier_volumes(sched, "all_reduce", 1.0, 2.0)


def _check_alltoall(sched: Schedule) -> None:
    topo = sched.topo
    m = sched.nbytes
    M, c = topo.n_machines, topo.procs_per_machine
    if sched.name == "alltoall_flat_pairwise":
        know = _replay_knowledge(sched)
        P = topo.n_procs
        for q in range(P):
            lack = [p for p in range(P) if p != q and ("a2a", p, q) not in know[q]]
            if lack:
                raise ScheduleError(f"all_to_all: {q} missing from {lack}")
    else:
        # volume check: every ordered machine pair must move c*c*m bytes
        pair_bytes: dict[tuple[int, int], float] = defaultdict(float)
        for op in sched.all_ops():
            if isinstance(op, Send) and not topo.co_located(op.src, op.dst):
                key = (topo.machine_of(op.src), topo.machine_of(op.dst))
                pair_bytes[key] += op.nbytes
        for i in range(M):
            for j in range(M):
                if i == j:
                    continue
                if pair_bytes[(i, j)] < c * c * m * 0.999:
                    raise ScheduleError(
                        f"all_to_all: machines {i}->{j} moved "
                        f"{pair_bytes[(i, j)]} < {c * c * m}"
                    )


@dataclass(frozen=True)
class SimResult:
    name: str
    collective: str
    t_rounds: float
    t_async: float
    n_rounds: int
    global_bytes: float
    local_bytes: float


def evaluate(sched: Schedule) -> SimResult:
    """Validate, semantics-check, and time a schedule under both views."""
    validate(sched)
    check_semantics(sched)
    return SimResult(
        name=sched.name,
        collective=sched.collective,
        t_rounds=simulate_rounds(sched, check=False),
        t_async=simulate_async(sched, check=False),
        n_rounds=sched.n_rounds,
        global_bytes=sched.total_global_bytes(),
        local_bytes=sched.total_local_bytes(),
    )
