"""The paper's primary contribution: a communication model for clusters of
multi-core machines (Task & Chauhan, 2008), realized as

  * a formal two-tier cost model with the paper's three rules
    (``topology``, ``simulator``),
  * explicit collective schedules under that model (``schedules``),
  * a registry-based collectives API -- ``repro.comm`` -- binding each
    plannable strategy to its runnable shard_map implementation and
    selecting the best schedule per topology and message size
    (``comm.CommContext``).

``core.planner`` and ``core.collectives`` remain as thin deprecation shims
over ``repro.comm``; new code should use ``repro.comm`` directly::

    from repro import comm
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    pc = ctx.plan("all_reduce", nbytes, lossy_ok=True)   # callable plan
"""

from .topology import (  # noqa: F401
    TOPOLOGY_PRESETS,
    ClusterTopology,
    LinkTier,
    paper_smp_cluster,
    topology_preset,
    tpu_v5e_3tier,
    tpu_v5e_cluster,
)

# Planner names resolve lazily (PEP 562): ``repro.comm`` itself imports the
# schedule generators through this package, so the shimmed planner surface
# must not be pulled in eagerly.
_PLANNER_NAMES = (
    "CollectivePolicy",
    "Plan",
    "best_plan",
    "enumerate_plans",
    "make_policy",
)


def __getattr__(name: str):
    if name in _PLANNER_NAMES:
        from . import planner

        return getattr(planner, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
