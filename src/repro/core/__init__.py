"""The paper's primary contribution: a communication model for clusters of
multi-core machines (Task & Chauhan, 2008), realized as

  * a formal two-tier cost model with the paper's three rules
    (``topology``, ``simulator``),
  * explicit collective schedules under that model (``schedules``),
  * a cost-driven planner that picks the best schedule per topology and
    message size (``planner``),
  * runnable shard_map realizations of the chosen schedules (``collectives``).
"""

from .planner import (  # noqa: F401
    CollectivePolicy,
    Plan,
    best_plan,
    enumerate_plans,
    make_policy,
)
from .topology import (  # noqa: F401
    ClusterTopology,
    LinkTier,
    paper_smp_cluster,
    tpu_v5e_cluster,
)
