"""DEPRECATED location for the runnable collectives -- use ``repro.comm``.

The executable strategy implementations now live in ``repro.comm.impls``
where each is registered against its schedule generator (one
``CollectiveSpec`` per (collective, strategy)), and the production pod-tier
gradient sync lives in ``repro.comm.grad_sync``.  This module re-exports
the old names so existing imports keep working:

  * ``q8_encode`` / ``q8_decode`` / ``Q8_BLOCK`` -- the int8 block codec,
  * ``manual_all_reduce_*`` / ``manual_all_to_all_*`` -- runnable schedules,
  * ``MANUAL_ALL_REDUCE`` -- now a *derived view* of the registry
    (impl tag -> runnable fn), no longer a hand-maintained dict,
  * ``pod_sync_grads`` -- the shard_map-region gradient sync.
"""

from __future__ import annotations

from repro.comm import executable_view
from repro.comm.grad_sync import (  # noqa: F401
    _pod_mean_flat,
    _pod_mean_q8,
    pod_sync_grads,
)
from repro.comm.impls import (  # noqa: F401
    Q8_BLOCK,
    manual_all_gather_flat,
    manual_all_gather_hier,
    manual_all_reduce_flat,
    manual_all_reduce_hier,
    manual_all_reduce_hier_q8,
    manual_all_to_all_flat,
    manual_all_to_all_hier,
    manual_broadcast_flat,
    manual_broadcast_hier,
    q8_decode,
    q8_decode_sum,
    q8_encode,
)

MANUAL_ALL_REDUCE = executable_view("all_reduce")
