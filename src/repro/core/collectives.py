"""Executable realizations of the planner's collective strategies.

Two layers:

1. ``manual_*`` -- fully-manual shard_map collectives over a ("mach", "core")
   mesh.  These are the paper's schedules as runnable JAX: the flat variant
   crosses the machine axis with whole vectors; the hierarchical variants
   reduce-scatter locally first (Rule 1/2), cross the machine tier with
   1/core-sized shards on every core's link in parallel (Rule 3), and
   all-gather locally last.  Verified numerically against jnp references in
   tests (8 fake devices, subprocess).

2. ``pod_sync_grads`` -- the production gradient-sync stage.  The trainer
   runs the model under GSPMD on the ("data", "model") axes and keeps the
   "pod" axis *manual* (shard_map ``axis_names={'pod'}``): the inter-pod DCN
   tier -- the paper's "global edges" -- is always scheduled explicitly by
   the planner, never left to the partitioner.

The int8 compression path (``q8``) quantizes blocks of 64 values to int8
with an f32 scale before crossing the DCN tier: 4.25 bytes -> 1.0625 bytes
per f32 value, a ~4x cut of the global-tier collective term.  It is lossy
and opt-in (``lossy_grad_ok``).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

Q8_BLOCK = 64


# ----------------------------------------------------------------------
# int8 block codec (for the DCN tier)
# ----------------------------------------------------------------------

def q8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """Blockwise symmetric int8 quantization over the last axis.

    Blocks the LAST dim only (padded to a multiple of Q8_BLOCK) and keeps
    the leading dims -- no giant flatten, so >2^31-element tensors (the
    stacked 40x8192x22528 mlp grads) stay within int32 index arithmetic.
    Returns (q [..., nblk, B], scales [..., nblk, 1], last_dim)."""
    last = x.shape[-1]
    pad = (-last) % Q8_BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], -1, Q8_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), last


def q8_decode(q: jax.Array, scale: jax.Array, last: int, shape, dtype) -> jax.Array:
    out = (q.astype(jnp.float32) * scale)
    out = out.reshape(*out.shape[:-2], -1)[..., :last]
    return out.reshape(shape).astype(dtype)


# ----------------------------------------------------------------------
# Fully-manual two-tier collectives (the paper's schedules, runnable)
# ----------------------------------------------------------------------

def manual_all_reduce_flat(x: jax.Array, mach_axis: str, core_axis: str) -> jax.Array:
    """Hierarchy-oblivious all-reduce: one psum over the joint axes.

    Every proc's full vector crosses whatever links the runtime picks --
    the baseline the paper says existing algorithms default to.
    """
    return lax.psum(x, (mach_axis, core_axis))


def manual_all_reduce_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """The paper's all-reduce (allreduce_hier_par_bw schedule).

    Phase 1 (local):  reduce-scatter over the core axis (Rule 1 reads,
                      cheap tier).
    Phase 2 (global): all-reduce of the 1/c shard over the machine axis --
                      every core drives its machine's external links with a
                      distinct shard simultaneously (Rule 3).
    Phase 3 (local):  all-gather over the core axis (Rule 1 write).
    """
    c = lax.axis_size(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % c
    flat = jnp.pad(flat, (0, pad))
    s = lax.psum_scatter(flat, core_axis, scatter_dimension=0, tiled=True)
    s = lax.psum(s, mach_axis)
    full = lax.all_gather(s, core_axis, axis=0, tiled=True)
    return full[: x.size].reshape(x.shape)


def manual_all_reduce_hier_q8(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Hierarchical all-reduce with int8-compressed global tier.

    The machine-tier exchange moves int8 payload + f32 block scales instead
    of full-precision values (lossy; gradient-sync use only).
    """
    c = lax.axis_size(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % c
    flat = jnp.pad(flat, (0, pad))
    s = lax.psum_scatter(flat, core_axis, scatter_dimension=0, tiled=True)
    q, scale, last = q8_encode(s)
    # Sum of per-machine dequantized contributions: gather both and reduce
    # locally (machine count is small; payload on the wire is compressed).
    qg = lax.all_gather(q, mach_axis, axis=0, tiled=False)
    sg = lax.all_gather(scale, mach_axis, axis=0, tiled=False)
    deq = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    s = q8_decode(deq / 1.0, jnp.ones_like(sg[0]), last, s.shape, s.dtype)
    full = lax.all_gather(s, core_axis, axis=0, tiled=True)
    return full[: x.size].reshape(x.shape)


def manual_all_to_all_flat(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Flat all-to-all over the joint (mach, core) axes.

    x: [P, ...] where P = n_mach * n_core; chunk j goes to global proc j.
    """
    # split the leading dim over both axes: [M, C, ...]
    n_mach = lax.axis_size(mach_axis)
    n_core = lax.axis_size(core_axis)
    xm = x.reshape(n_mach, n_core, *x.shape[1:])
    xm = lax.all_to_all(xm, mach_axis, split_axis=0, concat_axis=0, tiled=False)
    xm = lax.all_to_all(xm, core_axis, split_axis=1, concat_axis=1, tiled=False)
    return xm.reshape(n_mach * n_core, *x.shape[1:])


def manual_all_to_all_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Kumar-style two-tier all-to-all (alltoall_hier_par schedule).

    Phase 1: local all-to-all consolidates per-destination-machine bundles
             onto the egress cores (cheap tier).
    Phase 2: one machine-tier all-to-all of consolidated bundles, all egress
             links in parallel (Rule 3).
    Phase 3: local all-to-all scatters received bundles to their final cores
             (Rule 1 writes in the model; an ICI shuffle on TPU).

    Same bytes as flat on the global tier but M-1 consolidated transfers per
    machine instead of P-1 small ones, and no duplicate DCN crossings.
    """
    n_mach = lax.axis_size(mach_axis)
    n_core = lax.axis_size(core_axis)
    payload = x.shape[1:]
    xm = x.reshape(n_mach, n_core, *payload)  # [dst_mach, dst_core, ...]
    # Global phase: one machine-tier exchange of consolidated bundles --
    # each core crosses the DCN exactly once per destination machine
    # (consolidation; Rule 3 keeps every core's link busy simultaneously).
    xm = lax.all_to_all(xm, mach_axis, split_axis=0, concat_axis=0, tiled=True)
    # now [src_mach, dst_core, ...]; rows came from (src_mach, my_core)
    # Local phase: core-tier shuffle to final destinations (cheap tier;
    # a shared-memory write in the paper's model, an ICI shuffle on TPU).
    xm = lax.all_to_all(xm, core_axis, split_axis=1, concat_axis=0, tiled=True)
    # now [src_core * src_mach, 1, ...] -- reorder to source-major layout
    xm = xm.reshape(n_core, n_mach, *payload)
    xm = jnp.swapaxes(xm, 0, 1)
    return xm.reshape(n_mach * n_core, *payload)


MANUAL_ALL_REDUCE = {
    "flat": manual_all_reduce_flat,
    "hier": manual_all_reduce_hier,
    "hier_bw": manual_all_reduce_hier,      # same runnable schedule
    "hier_q8": manual_all_reduce_hier_q8,
    "hier_bw_q8": manual_all_reduce_hier_q8,
}


# ----------------------------------------------------------------------
# Production pod-tier gradient sync
# ----------------------------------------------------------------------

def _pod_mean_flat(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    return lax.psum(g, pod_axis) / n_pods


def _pod_mean_q8(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    q, scale, n = q8_encode(g)
    qg = lax.all_gather(q, pod_axis, axis=0, tiled=False)
    sg = lax.all_gather(scale, pod_axis, axis=0, tiled=False)
    acc = jnp.sum(qg.astype(jnp.float32) * sg, axis=0) / n_pods
    return q8_decode(acc, jnp.ones_like(sg[0]), n, g.shape, g.dtype)


def pod_sync_grads(
    grads: Any, strategy: str, pod_axis: str = "pod"
) -> Any:
    """Average gradients across pods (the DCN tier), planner-chosen strategy.

    Called inside a ``shard_map(..., axis_names={pod_axis})`` region: the
    'data'/'model' axes stay GSPMD-auto, so each leaf here is the pod-local
    gradient, still sharded over the intra-pod mesh.  Because the trainer
    FSDP-shards parameters over 'data', each chip's leaf shard is distinct,
    and this psum is exactly the paper's parallel-egress exchange: 256
    cross-pod pairs each moving 1/256th of the gradient simultaneously.

    strategy:
      'flat'    -- psum full-precision shards across pods.
      'q8'      -- int8-compress shards before crossing the DCN tier (lossy).
    """
    n_pods = lax.axis_size(pod_axis)
    if strategy == "flat":
        f = functools.partial(_pod_mean_flat, pod_axis=pod_axis, n_pods=n_pods)
    elif strategy == "q8":
        f = functools.partial(_pod_mean_q8, pod_axis=pod_axis, n_pods=n_pods)
    else:
        raise ValueError(f"unknown pod sync strategy {strategy!r}")
    return jax.tree.map(f, grads)
