"""DEPRECATED free-function planner surface -- use ``repro.comm``.

The planning logic lives in ``repro.comm.context`` (``CommContext`` /
``PlannedCollective``), backed by the strategy registry that binds every
plannable strategy to its runnable implementation (or marks it model-only).
This module re-exports the old names so existing callers and tests keep
working:

  * ``Plan``, ``enumerate_plans``, ``best_plan`` -- same semantics; ``Plan``
    gained ``model_only`` and ``root`` fields, and ``Plan.impl`` is None for
    model-only strategies instead of a dangling tag.
  * ``CollectivePolicy`` / ``make_policy`` -- unchanged dataclass, now built
    on the registry-backed planner.
  * ``Q8_GLOBAL_FACTOR`` -- moved to ``repro.comm.impls``.

The seed's ``_IMPL_OF_STRATEGY`` dict is gone: the impl tag is part of each
``CollectiveSpec`` and validated at import time, so a plan can no longer
name an implementation that does not exist.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.comm import (  # noqa: F401  (re-exported legacy surface)
    Plan,
    Q8_GLOBAL_FACTOR,
    best_plan,
    enumerate_plans,
)
from .topology import ClusterTopology


@dataclass(frozen=True)
class CollectivePolicy:
    """The planner's decisions for one training/serving configuration.

    Consumed by ``train.steps`` / ``repro.comm`` to pick the gradient
    sync path and the MoE dispatch path.
    """

    grad_sync: Plan
    moe_all_to_all: Plan | None = None

    @property
    def grad_sync_impl(self) -> str:
        return self.grad_sync.impl


def make_policy(
    topo: ClusterTopology,
    grad_bytes: float,
    moe_bytes: float | None = None,
    lossy_grad_ok: bool = False,
) -> CollectivePolicy:
    return CollectivePolicy(
        grad_sync=best_plan(topo, "all_reduce", grad_bytes, lossy_ok=lossy_grad_ok),
        moe_all_to_all=(
            best_plan(topo, "all_to_all", moe_bytes) if moe_bytes else None
        ),
    )
