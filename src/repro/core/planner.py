"""Cost-model-driven collective planning.

The planner is the paper's punchline: given a topology and a collective, it
enumerates candidate schedules (flat / hierarchical-leader / the paper's
parallel-egress hierarchical), costs each under the round-based model, and
returns the argmin.  The runtime (``core.collectives``) consumes the chosen
plan's ``impl`` tag to pick the matching shard_map implementation.

Costing exploits that every generator's round-based time is exactly affine in
the message size m (each op's bytes is an integer multiple of m):
``t(m) = A + B*m``.  We evaluate the schedule at two message sizes once per
(topology, collective, strategy) and cache the coefficients, so planning is
O(1) per query even for 512-chip topologies.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import lru_cache

from . import schedules as S
from .simulator import simulate_rounds, validate
from .topology import ClusterTopology

# Executable implementations living in core.collectives, keyed by impl tag.
_IMPL_OF_STRATEGY = {
    "flat": "flat",
    "hier_seq": "hier_seq",
    "hier_par": "hier",
    "hier_par_bw": "hier_bw",
    "hier_par_bw_q8": "hier_bw_q8",
}

# Quantized-DCN variant: global-tier bytes shrink by this factor (fp32 ->
# int8 values + per-block scales).  Applied to all_reduce only (gradient
# sync); lossy, so the planner reports it separately and the runtime only
# selects it when the caller opts in.
Q8_GLOBAL_FACTOR = 0.2656  # 1/4 payload + 1/64-block fp32 scales


@dataclass(frozen=True)
class Plan:
    collective: str
    strategy: str
    impl: str
    nbytes: float
    t_rounds: float
    n_rounds: int
    global_bytes: float
    local_bytes: float
    lossy: bool = False

    def speedup_vs(self, other: "Plan") -> float:
        return other.t_rounds / self.t_rounds


def _scale_global_bytes(sched: S.Schedule, factor: float) -> S.Schedule:
    out = S.Schedule(
        sched.name + "_q8", sched.collective, sched.topo, sched.nbytes,
        root=sched.root,
    )
    for rnd in sched.rounds:
        nr = out.new_round()
        for op in rnd.ops:
            if isinstance(op, S.Send) and not sched.topo.co_located(op.src, op.dst):
                nr.add(dataclasses.replace(op, nbytes=op.nbytes * factor))
            else:
                nr.add(op)
    return out


@lru_cache(maxsize=4096)
def _affine_cost(
    topo: ClusterTopology, collective: str, strategy: str, root: int
) -> tuple:
    """(A, B, n_rounds, gB, lB) with t(m) = A + B*m, global/local bytes = m*(gB, lB)."""
    lossy = strategy.endswith("_q8")
    base = strategy[:-3] if lossy else strategy
    m1, m2 = 1024.0, 2048.0

    def mk(m):
        sched = S.build(topo, collective, base, m, root=root, payloads=False)
        if lossy:
            sched = _scale_global_bytes(sched, Q8_GLOBAL_FACTOR)
        return sched

    s1, s2 = mk(m1), mk(m2)
    validate(s1)  # non-strict: flat schedules may oversubscribe NICs
    t1, t2 = simulate_rounds(s1, check=False), simulate_rounds(s2, check=False)
    B = (t2 - t1) / (m2 - m1)
    A = t1 - B * m1
    return (A, B, s1.n_rounds, s1.total_global_bytes() / m1, s1.total_local_bytes() / m1)


def available_strategies(collective: str, lossy_ok: bool = False) -> list:
    out = list(S.GENERATORS[collective].keys())
    if collective == "all_reduce" and lossy_ok:
        out.append("hier_par_bw_q8")
    return out


def enumerate_plans(
    topo: ClusterTopology,
    collective: str,
    nbytes: float,
    root: int = 0,
    lossy_ok: bool = False,
) -> list:
    """All candidate plans for a collective, sorted by modelled time."""
    plans = []
    for strat in available_strategies(collective, lossy_ok):
        A, B, n_rounds, gB, lB = _affine_cost(topo, collective, strat, root)
        plans.append(
            Plan(
                collective=collective,
                strategy=strat,
                impl=_IMPL_OF_STRATEGY[strat],
                nbytes=nbytes,
                t_rounds=A + B * nbytes,
                n_rounds=n_rounds,
                global_bytes=gB * nbytes,
                local_bytes=lB * nbytes,
                lossy=strat.endswith("_q8"),
            )
        )
    plans.sort(key=lambda p: p.t_rounds)
    return plans


def best_plan(
    topo: ClusterTopology,
    collective: str,
    nbytes: float,
    root: int = 0,
    lossy_ok: bool = False,
) -> Plan:
    return enumerate_plans(topo, collective, nbytes, root, lossy_ok)[0]


@dataclass(frozen=True)
class CollectivePolicy:
    """The planner's decisions for one training/serving configuration.

    Consumed by ``train.steps`` / ``core.collectives`` to pick the gradient
    sync path and the MoE dispatch path.
    """

    grad_sync: Plan
    moe_all_to_all: Plan | None = None

    @property
    def grad_sync_impl(self) -> str:
        return self.grad_sync.impl


def make_policy(
    topo: ClusterTopology,
    grad_bytes: float,
    moe_bytes: float | None = None,
    lossy_grad_ok: bool = False,
) -> CollectivePolicy:
    return CollectivePolicy(
        grad_sync=best_plan(topo, "all_reduce", grad_bytes, lossy_ok=lossy_grad_ok),
        moe_all_to_all=(
            best_plan(topo, "all_to_all", moe_bytes) if moe_bytes else None
        ),
    )
