"""Collective-communication schedules under the multi-core cluster model.

A Schedule is an explicit, validatable plan: a sequence of rounds, each round
holding point-to-point transfers (telephone edges, local or global) and
shared-memory writes (paper Rule 1).  Generators below produce schedules for
broadcast / gather / all-gather / all-reduce / all-to-all in three styles:

  * ``flat``       -- hierarchy-oblivious (what classic algorithms do; the
                      paper's strawman),
  * ``hier_seq``   -- hierarchical with single-leader machines (the "previous
                      approaches" of [3] the paper criticizes),
  * ``hier_par``   -- hierarchy- and Rule-3-aware: parallel egress, local
                      writes for fan-out, clique reads for fan-in (the
                      paper's proposal).

Payloads are modelled as frozensets of chunk ids so the simulator can check
collective *semantics* (who must know what at the end).  Building payload
sets is O(P^2) memory for some collectives, so every generator takes
``payloads=False`` to produce a structurally identical schedule with empty
payloads -- the planner uses that cheap mode on production-size topologies
(512 chips), while tests verify on small topologies that both modes have
identical rounds/bytes/cost.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

from .topology import ClusterTopology

EMPTY = frozenset()


# ----------------------------------------------------------------------
# Schedule IR
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Send:
    """Point-to-point transfer of a payload (one telephone edge).

    Local sends (same machine) are Rule-1 *reads*: the destination reads the
    source's buffer across the intra-machine clique.
    """

    src: int
    dst: int
    nbytes: float
    payload: frozenset = EMPTY


@dataclass(frozen=True)
class LocalWrite:
    """Rule 1: the writer publishes a payload to co-located readers in O(1)."""

    writer: int
    readers: tuple
    nbytes: float
    payload: frozenset = EMPTY


Op = Send | LocalWrite


@dataclass
class Round:
    ops: list = field(default_factory=list)

    def add(self, op: Op) -> None:
        self.ops.append(op)


@dataclass
class Schedule:
    name: str
    collective: str
    topo: ClusterTopology
    nbytes: float                      # per-chunk message size m
    rounds: list = field(default_factory=list)
    root: int = 0

    def new_round(self) -> Round:
        r = Round()
        self.rounds.append(r)
        return r

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def all_ops(self) -> Iterable[Op]:
        for r in self.rounds:
            yield from r.ops

    def total_global_bytes(self) -> float:
        return sum(
            op.nbytes
            for op in self.all_ops()
            if isinstance(op, Send) and not self.topo.co_located(op.src, op.dst)
        )

    def total_local_bytes(self) -> float:
        return sum(
            op.nbytes
            for op in self.all_ops()
            if isinstance(op, Send) and self.topo.co_located(op.src, op.dst)
        )


def _pay(payloads: bool, items) -> frozenset:
    return frozenset(items) if payloads else EMPTY


# ======================================================================
# BROADCAST
# ======================================================================

def bcast_flat_binomial(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Hierarchy-oblivious binomial broadcast over all P procs.

    ceil(log2 P) rounds; edges are local or global by accident of rank
    numbering -- this is the paper's motivating bad baseline.
    """
    sched = Schedule("bcast_flat_binomial", "broadcast", topo, m, root=root)
    P = topo.n_procs
    payload = _pay(payloads, [("bcast", root)])
    have = [root]
    others = [p for p in range(P) if p != root]
    while others:
        rnd = sched.new_round()
        n = min(len(have), len(others))
        batch, others = others[:n], others[n:]
        for s, d in zip(have, batch):
            rnd.add(Send(s, d, m, payload))
        have.extend(batch)
    return sched


def bcast_hier_seq(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Hierarchical-with-single-leader broadcast ("previous approaches" [3]).

    Machines are opaque nodes: binomial tree over machine leaders (one egress
    link each -- ignores Rule 3), then one local write per machine (Rule 1).
    """
    sched = Schedule("bcast_hier_seq", "broadcast", topo, m, root=root)
    payload = _pay(payloads, [("bcast", root)])
    M = topo.n_machines
    root_mach = topo.machine_of(root)
    leaders = {root_mach: root}
    covered = [root_mach]
    remaining = [j for j in range(M) if j != root_mach]
    while remaining:
        rnd = sched.new_round()
        n = min(len(covered), len(remaining))
        batch, remaining = remaining[:n], remaining[n:]
        for src_mach, dst_mach in zip(covered, batch):
            leader = next(iter(topo.procs_of(dst_mach)))
            rnd.add(Send(leaders[src_mach], leader, m, payload))
            leaders[dst_mach] = leader
        covered.extend(batch)
    rnd = sched.new_round()
    for mach, leader in leaders.items():
        readers = tuple(p for p in topo.procs_of(mach) if p != leader)
        if readers:
            rnd.add(LocalWrite(leader, readers, m, payload))
    return sched


def bcast_hier_par(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """The paper's broadcast: local write + degree-parallel egress.

    Once a machine holds the value every proc holds it (Rule 1 write), so the
    machine can seed up to ``degree`` new machines per round (Rule 3):
    coverage multiplies by (degree+1) per global round ==>
    ceil(log_{d+1}(M)) global rounds.
    """
    sched = Schedule("bcast_hier_par", "broadcast", topo, m, root=root)
    payload = _pay(payloads, [("bcast", root)])
    d = min(topo.degree, topo.procs_per_machine)
    root_mach = topo.machine_of(root)

    # Round 0: publish inside the root machine so all its procs can send.
    rnd = sched.new_round()
    readers = tuple(p for p in topo.procs_of(root_mach) if p != root)
    if readers:
        rnd.add(LocalWrite(root, readers, m, payload))

    covered = [root_mach]
    remaining = [j for j in range(topo.n_machines) if j != root_mach]
    while remaining:
        rnd = sched.new_round()
        new = []
        k = 0
        for src_mach in covered:
            for s in list(topo.procs_of(src_mach))[:d]:
                if k >= len(remaining):
                    break
                dst_mach = remaining[k]
                leader = next(iter(topo.procs_of(dst_mach)))
                rnd.add(Send(s, leader, m, payload))
                # Rule 2: intra-machine publish chains inside the same global
                # round (internal edges hide in the round length).
                lw = tuple(p for p in topo.procs_of(dst_mach) if p != leader)
                if lw:
                    rnd.add(LocalWrite(leader, lw, m, payload))
                new.append(dst_mach)
                k += 1
            if k >= len(remaining):
                break
        covered.extend(new)
        remaining = remaining[k:]
    return sched


# ======================================================================
# GATHER  (root ends with every proc's chunk; payloads concatenate)
# ======================================================================

def gather_flat_binomial(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Inverse binomial tree to root, hierarchy-oblivious."""
    sched = Schedule("gather_flat_binomial", "gather", topo, m, root=root)
    P = topo.n_procs
    unrel = lambda r: (r + root) % P
    counts = {p: 1 for p in range(P)}
    know = {p: {p} for p in range(P)} if payloads else None
    k = 0
    while (1 << k) < P:
        rnd = sched.new_round()
        for r in range(1 << k, P, 1 << (k + 1)):
            src, dst = unrel(r), unrel(r - (1 << k))
            pay = _pay(payloads, know[src]) if payloads else EMPTY
            rnd.add(Send(src, dst, m * counts[src], pay))
            counts[dst] += counts[src]
            if payloads:
                know[dst] |= know[src]
        k += 1
    return sched


def _lockstep_local_combine(
    sched: Schedule,
    topo: ClusterTopology,
    heads: dict,
    counts: dict,
    know,
    m: float,
    payloads: bool,
    concat: bool,
) -> None:
    """Tree-combine each machine's procs onto its head, machines in lockstep.

    Rule 1 reads: each combine step is a local Send (clique read).  For
    ``concat`` collectives (gather) bytes grow with chunk counts; for
    reductions bytes stay m.
    """
    lives = {}
    for mach in range(topo.n_machines):
        head = heads[mach]
        lives[mach] = [head] + [p for p in topo.procs_of(mach) if p != head]
    while any(len(v) > 1 for v in lives.values()):
        rnd = sched.new_round()
        for mach, live in lives.items():
            if len(live) <= 1:
                continue
            half = (len(live) + 1) // 2
            for i in range(len(live) - half):
                src, dst = live[half + i], live[i]
                nb = m * counts[src] if concat else m
                pay = _pay(payloads, know[src]) if payloads else EMPTY
                rnd.add(Send(src, dst, nb, pay))
                counts[dst] += counts[src]
                if payloads:
                    know[dst] |= know[src]
            lives[mach] = live[:half]


def gather_hier_par(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """The paper's gather: clique-read local combine, then parallel ingress.

    Rule 1 says reads are NOT free: each machine tree-combines its procs'
    chunks over local clique edges (ceil(log2 c) local rounds), then machine
    buffers flow to the root machine, which ingests on up to ``degree`` links
    per round (Rule 3) into distinct procs, which the root finally reads.
    This schedule is *not* the inverse of the broadcast tree -- reproducing
    the paper's C2 asymmetry.
    """
    sched = Schedule("gather_hier_par", "gather", topo, m, root=root)
    c = topo.procs_per_machine
    M = topo.n_machines
    root_mach = topo.machine_of(root)
    d = min(topo.degree, c)

    counts = {p: 1 for p in range(topo.n_procs)}
    know = {p: {p} for p in range(topo.n_procs)} if payloads else None
    heads = {
        mach: (root if mach == root_mach else next(iter(topo.procs_of(mach))))
        for mach in range(M)
    }
    _lockstep_local_combine(sched, topo, heads, counts, know, m, payloads, concat=True)

    # Phase 2: machines ship combined buffers to the root machine.  Each
    # machine buffer is STRIPED across up to d ingress links landing on
    # distinct procs of the root machine (Rule 3 parallel ingress) -- this is
    # where gather stops being the inverse of broadcast: the root machine can
    # ingest on all links at once, but the root proc still has to *read*
    # every stripe (Rule 1).
    pending = [mach for mach in range(M) if mach != root_mach]
    recv_procs = [p for p in topo.procs_of(root_mach) if p != root] or [root]
    n_stripes = max(1, min(d, len(recv_procs)))
    ingress: list[tuple] = []
    if pending:
        # Rule 1 write: every remote head publishes its machine buffer so d
        # co-located procs can stripe it out in parallel (one shared round).
        if n_stripes > 1:
            rnd = sched.new_round()
            for mach in pending:
                head = heads[mach]
                readers = tuple(
                    p for p in list(topo.procs_of(mach))[:n_stripes] if p != head
                )
                if readers:
                    pay = _pay(payloads, know[head]) if payloads else EMPTY
                    rnd.add(LocalWrite(head, readers, m * counts[head], pay))
        # One transfer round per remote machine: its buffer striped across
        # the root machine's ingress links (Rule 3).
        for mach in pending:
            src_procs = list(topo.procs_of(mach))[:n_stripes]
            chunks = (
                sorted(know[heads[mach]])
                if payloads
                else [None] * counts[heads[mach]]
            )
            per = math.ceil(len(chunks) / len(src_procs))
            rnd = sched.new_round()
            for k, src in enumerate(src_procs):
                stripe = chunks[k * per:(k + 1) * per]
                if not stripe:
                    continue
                dst = recv_procs[k % len(recv_procs)]
                pay = _pay(payloads, [ch for ch in stripe if ch is not None])
                rnd.add(Send(src, dst, m * len(stripe), pay))
                if payloads:
                    know[dst] |= set(pay)
                ingress.append((dst, len(stripe), pay))

    # Phase 3: root reads the ingress procs' buffers (clique reads; the
    # root's receive port admits one read per round).
    for dst, cnt, pay in ingress:
        if dst == root:
            continue
        rnd = sched.new_round()
        rnd.add(Send(dst, root, m * cnt, pay))
        counts[root] += cnt
        if payloads:
            know[root] |= set(pay)
    return sched


# ======================================================================
# ALL-GATHER
# ======================================================================

def allgather_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic ring all-gather: P-1 rounds of m bytes, hierarchy-oblivious."""
    sched = Schedule("allgather_flat_ring", "all_gather", topo, m)
    P = topo.n_procs
    for step in range(P - 1):
        rnd = sched.new_round()
        for p in range(P):
            chunk_id = (p - step) % P
            rnd.add(Send(p, (p + 1) % P, m, _pay(payloads, [chunk_id])))
    return sched


def allgather_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Two-tier all-gather: local clique all-gather, striped machine ring,
    local write.

    Phase 2 stripes each machine's consolidated c*m buffer across d egress
    procs: d parallel machine-level rings each carrying ~c*m/d per step
    (Rule 3).  Phase 3 publishes received stripes via shared-memory writes
    (Rule 1).
    """
    sched = Schedule("allgather_hier_par", "all_gather", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)
    P = topo.n_procs
    know = {p: {p} for p in range(P)} if payloads else None
    counts = {p: 1 for p in range(P)}

    # Phase 1: local all-gather over the clique.  Recursive doubling when c
    # is a power of two, ring otherwise.
    if c > 1 and (c & (c - 1)) == 0:
        step = 1
        while step < c:
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    j = i ^ step
                    if i < j:
                        p, q = procs[i], procs[j]
                        pp = _pay(payloads, know[p]) if payloads else EMPTY
                        pq = _pay(payloads, know[q]) if payloads else EMPTY
                        rnd.add(Send(p, q, m * counts[p], pp))
                        rnd.add(Send(q, p, m * counts[q], pq))
                        tot = counts[p] + counts[q]
                        counts[p] = counts[q] = tot
                        if payloads:
                            merged = know[p] | know[q]
                            know[p] = set(merged)
                            know[q] = set(merged)
            step <<= 1
    elif c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                moves = []
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    src_chunk = procs[(i - step) % c]
                    moves.append((p, q, src_chunk))
                    rnd.add(Send(p, q, m, _pay(payloads, [src_chunk])))
                for p, q, ch in moves:
                    counts[q] += 1
                    if payloads:
                        know[q].add(ch)

    if M > 1:
        # Phase 2: striped ring over machines.  Egress proc k of machine i
        # sends stripe k of the machine's buffer to proc k of machine i+1.
        stripe_chunks: dict[tuple[int, int], list] = {}
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            per = math.ceil(c / d)
            for k in range(d):
                stripe_chunks[(mach, k)] = procs[k * per:(k + 1) * per]
        carry = dict(stripe_chunks)
        for _ in range(M - 1):
            rnd = sched.new_round()
            new_carry = {}
            for mach in range(M):
                nxt = (mach + 1) % M
                src_procs = list(topo.procs_of(mach))[:d]
                dst_procs = list(topo.procs_of(nxt))[:d]
                for k in range(d):
                    chunks = carry[(mach, k)]
                    if not chunks:
                        new_carry[(nxt, k)] = []
                        continue
                    rnd.add(
                        Send(
                            src_procs[k],
                            dst_procs[k],
                            m * len(chunks),
                            _pay(payloads, chunks),
                        )
                    )
                    counts[dst_procs[k]] += len(chunks)
                    if payloads:
                        know[dst_procs[k]] |= set(chunks)
                    new_carry[(nxt, k)] = chunks
            carry = new_carry

        # Phase 3: every egress proc publishes everything it accumulated.
        rnd = sched.new_round()
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            for k in range(d):
                w = procs[k]
                readers = tuple(p for p in procs if p != w)
                if readers:
                    pay = _pay(payloads, know[w]) if payloads else EMPTY
                    rnd.add(LocalWrite(w, readers, m * counts[w], pay))
                    if payloads:
                        for p in readers:
                            know[p] |= know[w]
    return sched


# ======================================================================
# ALL-REDUCE  (payload = contribution sets; message size fixed at m)
# ======================================================================

def allreduce_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic flat ring all-reduce: reduce-scatter then all-gather.

    2*(P-1) rounds of m/P bytes; ~2m bytes on the wire per proc, blind to
    which edges cross machines.
    """
    sched = Schedule("allreduce_flat_ring", "all_reduce", topo, m)
    P = topo.n_procs
    shard_m = m / P
    holdings = (
        [{s: {("rs", s, p)} for s in range(P)} for p in range(P)]
        if payloads
        else None
    )
    for phase in range(2):  # 0 = reduce-scatter, 1 = all-gather
        for step in range(P - 1):
            rnd = sched.new_round()
            moves = []
            for p in range(P):
                if phase == 0:
                    shard = (p - step) % P
                else:
                    shard = (p + 1 - step) % P
                if payloads:
                    pay = frozenset(holdings[p][shard])
                else:
                    pay = EMPTY
                moves.append((p, (p + 1) % P, shard, pay))
                rnd.add(Send(p, (p + 1) % P, shard_m, pay))
            if payloads:
                for p, q, shard, pay in moves:
                    holdings[q][shard] |= set(pay)
    return sched


def reducescatter_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic flat ring reduce-scatter: P-1 rounds of m/P bytes.

    The first half of ``allreduce_flat_ring``: proc p ends holding the
    fully reduced shard (p+1) % P.  Hierarchy-oblivious -- ring edges cross
    machine seams blind, so on multi-core clusters the simulator charges
    the shared-NIC serialization just like the flat all-reduce.
    """
    sched = Schedule("reducescatter_flat_ring", "reduce_scatter", topo, m)
    P = topo.n_procs
    shard_m = m / P
    holdings = (
        [{s: {("rs", s, p)} for s in range(P)} for p in range(P)]
        if payloads
        else None
    )
    for step in range(P - 1):
        rnd = sched.new_round()
        moves = []
        for p in range(P):
            shard = (p - step) % P
            pay = frozenset(holdings[p][shard]) if payloads else EMPTY
            moves.append((p, (p + 1) % P, shard, pay))
            rnd.add(Send(p, (p + 1) % P, shard_m, pay))
        if payloads:
            for p, q, shard, pay in moves:
                holdings[q][shard] |= set(pay)
    return sched


def reducescatter_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Hierarchy-aware reduce-scatter (Rules 1+3; bandwidth-optimal).

    The first half of ``allreduce_hier_par_bw``:

    Phase 1: intra-machine ring reduce-scatter -- (c-1) local rounds of m/c;
             proc i of each machine ends holding reduced local shard (i+1)%c.
    Phase 2: cross-machine ring reduce-scatter run independently per local
             shard (Rule 3: all c procs drive their machine's egress links
             at once) -- (M-1) global rounds of m/(c*M) sub-shards.

    Every proc ends with 1/P of the fully reduced vector; global bytes per
    machine m*(M-1)/M -- half an all-reduce, the bandwidth-optimal exchange
    the bucketed gradient sync is built on.
    """
    sched = Schedule("reducescatter_hier_par", "reduce_scatter", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    P = topo.n_procs
    shard_m = m / c
    holdings = (
        [
            {s: {("lrs", topo.machine_of(p), s, p % c)} for s in range(c)}
            for p in range(P)
        ]
        if payloads
        else None
    )

    # Phase 1: local ring reduce-scatter (per machine, lockstep).
    if c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            moves = []
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    shard = (i - step) % c
                    pay = (
                        frozenset(holdings[p][shard]) if payloads else EMPTY
                    )
                    rnd.add(Send(p, q, shard_m, pay))
                    moves.append((q, shard, pay))
            if payloads:
                for q, shard, pay in moves:
                    holdings[q][shard] |= set(pay)

    # Phase 2: cross-machine ring reduce-scatter per shard (all in parallel).
    if M > 1:
        sub_m = shard_m / M
        for step in range(M - 1):
            rnd = sched.new_round()
            for mach in range(M):
                nxt = (mach + 1) % M
                for i in range(c):
                    src = list(topo.procs_of(mach))[i]
                    dst = list(topo.procs_of(nxt))[i]
                    rnd.add(
                        Send(
                            src,
                            dst,
                            sub_m,
                            _pay(payloads, [("xstripe", "rs", step, mach, i)]),
                        )
                    )
    return sched


def allreduce_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """The paper's all-reduce on a two-tier cluster.

    Phase 1 (Rule 1 reads):   local tree-reduce within each machine.
    Phase 2 (Rule 1 write):   head publishes so d egress procs hold the
                              machine vector, striped m/d each.
    Phase 3 (Rule 3):         inter-machine reduce-scatter + all-gather ring
                              run independently per stripe -- all d global
                              links busy every round.
    Phase 4 (Rule 1 write):   egress procs publish the reduced result.

    Global bytes per machine ~ 2*m*(M-1)/M (bandwidth-optimal), wall-clock
    divided by d.
    """
    sched = Schedule("allreduce_hier_par", "all_reduce", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)
    counts = {p: 1 for p in range(topo.n_procs)}
    know = (
        {p: {("ar", p)} for p in range(topo.n_procs)} if payloads else None
    )
    heads = {mach: next(iter(topo.procs_of(mach))) for mach in range(M)}
    _lockstep_local_combine(sched, topo, heads, counts, know, m, payloads, concat=False)

    if M == 1:
        rnd = sched.new_round()
        head = heads[0]
        readers = tuple(p for p in topo.procs_of(0) if p != head)
        if readers:
            pay = _pay(payloads, know[head]) if payloads else EMPTY
            rnd.add(LocalWrite(head, readers, m, pay))
        return sched

    # Phase 2: stripe distribution by shared-memory write.
    if d > 1:
        rnd = sched.new_round()
        for mach in range(M):
            head = heads[mach]
            egress = list(topo.procs_of(mach))[:d]
            readers = tuple(p for p in egress if p != head)
            if readers:
                pay = _pay(payloads, know[head]) if payloads else EMPTY
                rnd.add(LocalWrite(head, readers, m, pay))
                if payloads:
                    for p in readers:
                        know[p] |= know[head]

    # Phase 3: striped machine-level ring reduce-scatter + all-gather.
    stripe_m = m / d
    shard_m = stripe_m / M
    for phase in ("rs", "ag"):
        for step in range(M - 1):
            rnd = sched.new_round()
            for mach in range(M):
                nxt = (mach + 1) % M
                for k in range(d):
                    src = list(topo.procs_of(mach))[k]
                    dst = list(topo.procs_of(nxt))[k]
                    rnd.add(
                        Send(
                            src,
                            dst,
                            shard_m,
                            _pay(payloads, [("arstripe", phase, step, mach, k)]),
                        )
                    )

    # Phase 4: publish.
    rnd = sched.new_round()
    for mach in range(M):
        procs = list(topo.procs_of(mach))
        for k in range(d):
            w = procs[k]
            readers = tuple(p for p in procs if p != w)
            if readers:
                rnd.add(
                    LocalWrite(
                        w, readers, stripe_m, _pay(payloads, [("arfinal", k)])
                    )
                )
    return sched


def allreduce_hier_par_bw(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Bandwidth-optimal two-tier all-reduce (large-message regime).

    Found *with* the paper's cost model (see EXPERIMENTS.md): the tree-based
    ``allreduce_hier_par`` moves the full vector log2(c) times inside each
    machine, so at large m the local tier dominates.  This variant:

    Phase 1: intra-machine ring reduce-scatter -- (c-1) local rounds of m/c;
             proc i of each machine ends holding reduced local shard i.
    Phase 2: every proc ring-exchanges ITS shard across machines
             (reduce-scatter + all-gather over M, sub-shards m/(c*M)).
             All c procs hit the NICs at once; the simulator charges the
             ceil(c/degree) NIC serialization (Rule 3 as a limit), which
             still beats funnelling through one leader by ~degree.
    Phase 3: intra-machine ring all-gather -- (c-1) local rounds of m/c.

    Local bytes/proc ~ 2m, global bytes/machine ~ 2m(M-1)/M: both optimal.
    """
    sched = Schedule("allreduce_hier_par_bw", "all_reduce", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    P = topo.n_procs
    shard_m = m / c
    holdings = (
        [
            {s: {("lrs", topo.machine_of(p), s, p % c)} for s in range(c)}
            for p in range(P)
        ]
        if payloads
        else None
    )

    # Phase 1: local ring reduce-scatter (per machine, lockstep).
    if c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            moves = []
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    shard = (i - step) % c
                    pay = (
                        frozenset(holdings[p][shard]) if payloads else EMPTY
                    )
                    rnd.add(Send(p, q, shard_m, pay))
                    moves.append((q, shard, pay))
            if payloads:
                for q, shard, pay in moves:
                    holdings[q][shard] |= set(pay)

    # Phase 2: cross-machine ring RS + AG per shard (all shards in parallel).
    if M > 1:
        sub_m = shard_m / M
        for phase in ("rs", "ag"):
            for step in range(M - 1):
                rnd = sched.new_round()
                for mach in range(M):
                    nxt = (mach + 1) % M
                    for i in range(c):
                        src = list(topo.procs_of(mach))[i]
                        dst = list(topo.procs_of(nxt))[i]
                        rnd.add(
                            Send(
                                src,
                                dst,
                                sub_m,
                                _pay(payloads, [("xstripe", phase, step, mach, i)]),
                            )
                        )

    # Phase 3: local ring all-gather of the reduced shards.
    if c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    shard = (i + 1 - step) % c
                    rnd.add(
                        Send(
                            p, q, shard_m, _pay(payloads, [("fin", mach, shard)])
                        )
                    )
    return sched


# ======================================================================
# ALL-TO-ALL  (chunk (s, d) of m bytes must travel from proc s to proc d)
# ======================================================================

def alltoall_flat_pairwise(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic rotation all-to-all: P-1 rounds, proc p sends to p+r.

    Every (s,d) chunk crosses the network individually.  When a machine's c
    procs all send globally in one round they oversubscribe its ``degree``
    shared NICs; the simulator charges the ceil(c/degree) serialization --
    exactly the hidden cost the paper says flat algorithms suffer on
    multi-core clusters.
    """
    sched = Schedule("alltoall_flat_pairwise", "all_to_all", topo, m)
    P = topo.n_procs
    for r in range(1, P):
        rnd = sched.new_round()
        for p in range(P):
            q = (p + r) % P
            rnd.add(Send(p, q, m, _pay(payloads, [("a2a", p, q)])))
    return sched


def alltoall_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Kumar-style [3] two-tier all-to-all under the paper's model.

    Phase 1: intra-machine consolidation -- clique reads redistribute traffic
             so each of the d egress procs holds the outgoing stripes.
    Phase 2: machine-pair exchange, (M-1) rounds; round r machine i sends its
             consolidated c^2*m buffer for machine i+r striped over d egress
             procs (Rule 3).
    Phase 3: receiving procs publish to destinations by local writes (Rule 1).
    """
    sched = Schedule("alltoall_hier_par", "all_to_all", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)

    # Phase 1: local redistribution (ring over the clique, c-1 local rounds;
    # each proc forwards the bundle destined to egress proc p+1: M*m bytes).
    if c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    rnd.add(
                        Send(
                            p, q, m * M, _pay(payloads, [("a2a_loc", p, q, step)])
                        )
                    )

    # Phase 2: machine-pair exchanges with striped egress.
    if M > 1:
        consolidated = c * c * m
        stripe = consolidated / d
        for r in range(1, M):
            rnd = sched.new_round()
            for mach in range(M):
                dst_mach = (mach + r) % M
                src_procs = list(topo.procs_of(mach))[:d]
                dst_procs = list(topo.procs_of(dst_mach))[:d]
                for k in range(d):
                    rnd.add(
                        Send(
                            src_procs[k],
                            dst_procs[k],
                            stripe,
                            _pay(payloads, [("a2a_glob", mach, dst_mach, k)]),
                        )
                    )

        # Phase 3: publish received stripes (Rule 1 writes).
        rnd = sched.new_round()
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            for k in range(d):
                w = procs[k]
                readers = tuple(p for p in procs if p != w)
                if readers:
                    rnd.add(
                        LocalWrite(
                            w, readers, c * m, _pay(payloads, [("a2a_pub", mach, k)])
                        )
                    )
    return sched


# ----------------------------------------------------------------------
# Registry bridge
# ----------------------------------------------------------------------
#
# The generator functions above are *bound* to strategies (and to their
# runnable twins) in the ``repro.comm`` registry -- the single source of
# truth.  ``GENERATORS`` survives as a derived, read-only view for legacy
# callers; it is resolved lazily (PEP 562) to keep this module importable
# without pulling in jax through ``repro.comm.impls``.


def build(
    topo: ClusterTopology,
    collective: str,
    strategy: str,
    m: float,
    root: int = 0,
    payloads: bool = True,
) -> Schedule:
    """Build the schedule for a registered (collective, strategy) pair."""
    from repro import comm

    return comm.get_spec(collective, strategy).build_schedule(
        topo, m, root=root, payloads=payloads
    )


def __getattr__(name: str):
    if name == "GENERATORS":
        from repro import comm

        return comm.generators_view()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
