"""Collective-communication schedules under the multi-core cluster model.

A Schedule is an explicit, validatable plan: a sequence of rounds, each round
holding point-to-point transfers (telephone edges, local or global) and
shared-memory writes (paper Rule 1).  Generators below produce schedules for
broadcast / gather / all-gather / all-reduce / all-to-all in three styles:

  * ``flat``       -- hierarchy-oblivious (what classic algorithms do; the
                      paper's strawman),
  * ``hier_seq``   -- hierarchical with single-leader machines (the "previous
                      approaches" of [3] the paper criticizes),
  * ``hier_par``   -- hierarchy- and Rule-3-aware: parallel egress, local
                      writes for fan-out, clique reads for fan-in (the
                      paper's proposal).

Payloads are modelled as frozensets of chunk ids so the simulator can check
collective *semantics* (who must know what at the end).  Building payload
sets is O(P^2) memory for some collectives, so every generator takes
``payloads=False`` to produce a structurally identical schedule with empty
payloads -- the planner uses that cheap mode on production-size topologies
(512 chips), while tests verify on small topologies that both modes have
identical rounds/bytes/cost.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable

from .topology import ClusterTopology

EMPTY = frozenset()


# ----------------------------------------------------------------------
# Schedule IR
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class Send:
    """Point-to-point transfer of a payload (one telephone edge).

    Local sends (same machine) are Rule-1 *reads*: the destination reads the
    source's buffer across the intra-machine clique.
    """

    src: int
    dst: int
    nbytes: float
    payload: frozenset = EMPTY


@dataclass(frozen=True)
class LocalWrite:
    """Rule 1: the writer publishes a payload to co-located readers in O(1)."""

    writer: int
    readers: tuple
    nbytes: float
    payload: frozenset = EMPTY


Op = Send | LocalWrite


@dataclass
class Round:
    ops: list = field(default_factory=list)

    def add(self, op: Op) -> None:
        self.ops.append(op)


@dataclass
class Schedule:
    name: str
    collective: str
    topo: ClusterTopology
    nbytes: float                      # per-chunk message size m
    rounds: list = field(default_factory=list)
    root: int = 0

    def new_round(self) -> Round:
        r = Round()
        self.rounds.append(r)
        return r

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def all_ops(self) -> Iterable[Op]:
        for r in self.rounds:
            yield from r.ops

    def total_global_bytes(self) -> float:
        return sum(
            op.nbytes
            for op in self.all_ops()
            if isinstance(op, Send) and not self.topo.co_located(op.src, op.dst)
        )

    def total_local_bytes(self) -> float:
        return sum(
            op.nbytes
            for op in self.all_ops()
            if isinstance(op, Send) and self.topo.co_located(op.src, op.dst)
        )


def _pay(payloads: bool, items) -> frozenset:
    return frozenset(items) if payloads else EMPTY


# ======================================================================
# N-tier primitives
# ======================================================================
#
# The hierarchical generators compose these per-tier pieces instead of
# special-casing "local then global": rings run lockstep at any tier of the
# hierarchy, and fan-out/fan-in inside a machine recurses through the inner
# tiers (tier-0 shared-memory writes, seeding Sends across the tiers above).
# On a two-tier topology every helper degenerates to exactly the paper's
# two-phase schedule.

def _tier_rings(topo: ClusterTopology, level: int) -> list:
    """All rings over tier ``level``: each ring lists the ``fanout[level]``
    procs that share every hierarchical coordinate except coordinate
    ``level`` (so its edges are exactly tier-``level`` links)."""
    stride = topo.group_size(level)
    span = stride * topo.fanout[level]
    rings = []
    for outer in range(topo.n_procs // span):
        for off in range(stride):
            base = outer * span + off
            rings.append(
                [base + k * stride for k in range(topo.fanout[level])]
            )
    return rings


def _ring_rs_stage(
    sched: Schedule,
    topo: ClusterTopology,
    level: int,
    m: float,
    payloads: bool,
    holdings=None,
) -> None:
    """Lockstep ring reduce-scatter at tier ``level``.

    Working set per proc is m / group_size(level); each of the
    fanout[level] - 1 rounds moves m / group_size(level + 1) bytes per ring
    member.  At tier 0 the real contribution tokens flow through
    ``holdings`` (the semantics checker consumes them); outer tiers carry
    synthetic stripe tokens.
    """
    f = topo.fanout[level]
    if f <= 1:
        return
    send_m = m / topo.group_size(level + 1)
    rings = _tier_rings(topo, level)
    for step in range(f - 1):
        rnd = sched.new_round()
        moves = []
        for ring_id, ring in enumerate(rings):
            for i in range(f):
                p, q = ring[i], ring[(i + 1) % f]
                shard = (i - step) % f
                if level == 0 and holdings is not None and payloads:
                    pay = frozenset(holdings[p][shard])
                elif level == 0:
                    pay = EMPTY
                else:
                    pay = _pay(
                        payloads, [("xstripe", "rs", level, step, ring_id, i)]
                    )
                rnd.add(Send(p, q, send_m, pay))
                if level == 0 and holdings is not None:
                    moves.append((q, shard, pay))
        if payloads and level == 0 and holdings is not None:
            for q, shard, pay in moves:
                holdings[q][shard] |= set(pay)


def _ring_ag_stage(
    sched: Schedule,
    topo: ClusterTopology,
    level: int,
    m: float,
    payloads: bool,
    token: str = "xstripe",
) -> None:
    """Lockstep ring all-gather at tier ``level`` (the RS stage's inverse):
    fanout[level] - 1 rounds of m / group_size(level + 1) bytes."""
    f = topo.fanout[level]
    if f <= 1:
        return
    send_m = m / topo.group_size(level + 1)
    rings = _tier_rings(topo, level)
    for step in range(f - 1):
        rnd = sched.new_round()
        for ring_id, ring in enumerate(rings):
            for i in range(f):
                p, q = ring[i], ring[(i + 1) % f]
                rnd.add(
                    Send(
                        p, q, send_m,
                        _pay(payloads, [(token, "ag", level, step, ring_id, i)]),
                    )
                )


def _nearest_free(topo: ClusterTopology, knowers, used, target: int):
    """The free proc in ``knowers`` sharing the deepest group with
    ``target`` (so the seeding Send crosses the cheapest possible tier)."""
    best, best_level = None, None
    for p in sorted(knowers):
        if p in used:
            continue
        if p == target:
            return p
        level = topo.tier_index(p, target)
        if best is None or level < best_level:
            best, best_level = p, level
    return best


def _publish_all(sched: Schedule, topo: ClusterTopology, items) -> None:
    """Fan each (writer, nbytes, payload) out to every proc of its machine.

    On a two-tier cluster this is ONE round of Rule-1 LocalWrites (shared
    memory spans the machine).  Deeper hierarchies publish tier-recursively:
    every knowing proc writes its shared-memory (tier-0) group, and
    still-uncovered groups are seeded across the machine's inner link tiers
    in doubling rounds -- each seeding Send chains the landing group's write
    into the same round (the paper's internal-edges-hide rule).
    """
    items = [it for it in items if it is not None]
    if not items:
        return
    knows = [{w} for (w, _, _) in items]
    group_sets = [
        sorted(
            {topo.inner_group_of(p) for p in topo.procs_of(topo.machine_of(w))}
        )
        for (w, _, _) in items
    ]

    def uncovered(ix):
        return [
            g
            for g in group_sets[ix]
            if any(p not in knows[ix] for p in topo.group_procs(1, g))
        ]

    pending = [ix for ix in range(len(items)) if uncovered(ix)]
    while pending:
        rnd = sched.new_round()
        used_src: set = set()
        used_dst: set = set()
        for ix in pending:
            writer, nb, pay = items[ix]
            for g in uncovered(ix):
                procs = list(topo.group_procs(1, g))
                knowers = [p for p in procs if p in knows[ix]]
                if knowers:
                    w = next((p for p in knowers if p not in used_src), None)
                    if w is None:
                        continue
                    readers = tuple(p for p in procs if p != w)
                    if readers:
                        rnd.add(LocalWrite(w, readers, nb, pay))
                    used_src.add(w)
                    knows[ix].update(procs)
                else:
                    dst = next(
                        (
                            p for p in procs
                            if p not in used_dst and p not in used_src
                        ),
                        None,
                    )
                    if dst is None:
                        dst = next(
                            (p for p in procs if p not in used_dst), None
                        )
                    if dst is None:
                        continue
                    src = _nearest_free(topo, knows[ix], used_src, dst)
                    if src is None:
                        continue
                    rnd.add(Send(src, dst, nb, pay))
                    used_src.add(src)
                    used_dst.add(dst)
                    knows[ix].add(dst)
                    readers = tuple(p for p in procs if p != dst)
                    if readers and dst not in used_src:
                        # chained Rule-1 write in the same round: dst
                        # receives the seed and sources the publish (only
                        # when its send port is still free -- it may have
                        # seeded ANOTHER item's group earlier this round).
                        rnd.add(LocalWrite(dst, readers, nb, pay))
                        used_src.add(dst)
                        knows[ix].update(procs)
        pending = [ix for ix in pending if uncovered(ix)]


def _distribute(sched: Schedule, topo: ClusterTopology, items) -> None:
    """Get each (src, dests, nbytes, payload) from ``src`` to every proc in
    ``dests`` (all within src's machine).

    One Rule-1 write on a two-tier cluster; on deeper hierarchies dests in
    src's shared-memory group are written while dests in other groups are
    seeded across the inner tiers (chaining each landing group's write).
    """
    items = [it for it in items if it is not None and it[1]]
    if not items:
        return
    knows = [{s} for (s, _, _, _) in items]

    def missing(ix):
        return [p for p in items[ix][1] if p not in knows[ix]]

    pending = [ix for ix in range(len(items)) if missing(ix)]
    while pending:
        rnd = sched.new_round()
        used_src: set = set()
        used_dst: set = set()
        for ix in pending:
            src, dests, nb, pay = items[ix]
            by_group: dict[int, list] = {}
            for p in missing(ix):
                by_group.setdefault(topo.inner_group_of(p), []).append(p)
            for g, dst_list in sorted(by_group.items()):
                procs = list(topo.group_procs(1, g))
                knowers = [p for p in procs if p in knows[ix]]
                if knowers:
                    w = next((p for p in knowers if p not in used_src), None)
                    if w is None:
                        continue
                    readers = tuple(p for p in dst_list if p != w)
                    if readers:
                        rnd.add(LocalWrite(w, readers, nb, pay))
                    used_src.add(w)
                    knows[ix].update(dst_list)
                else:
                    dst = next(
                        (
                            p for p in dst_list
                            if p not in used_dst and p not in used_src
                        ),
                        None,
                    )
                    if dst is None:
                        dst = next(
                            (p for p in dst_list if p not in used_dst), None
                        )
                    if dst is None:
                        continue
                    s = _nearest_free(topo, knows[ix], used_src, dst)
                    if s is None:
                        continue
                    rnd.add(Send(s, dst, nb, pay))
                    used_src.add(s)
                    used_dst.add(dst)
                    knows[ix].add(dst)
                    readers = tuple(p for p in dst_list if p != dst)
                    if readers and dst not in used_src:
                        rnd.add(LocalWrite(dst, readers, nb, pay))
                        used_src.add(dst)
                        knows[ix].update(dst_list)
        pending = [ix for ix in pending if missing(ix)]


# ======================================================================
# BROADCAST
# ======================================================================

def bcast_flat_binomial(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Hierarchy-oblivious binomial broadcast over all P procs.

    ceil(log2 P) rounds; edges are local or global by accident of rank
    numbering -- this is the paper's motivating bad baseline.
    """
    sched = Schedule("bcast_flat_binomial", "broadcast", topo, m, root=root)
    P = topo.n_procs
    payload = _pay(payloads, [("bcast", root)])
    have = [root]
    others = [p for p in range(P) if p != root]
    while others:
        rnd = sched.new_round()
        n = min(len(have), len(others))
        batch, others = others[:n], others[n:]
        for s, d in zip(have, batch):
            rnd.add(Send(s, d, m, payload))
        have.extend(batch)
    return sched


def bcast_hier_seq(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Hierarchical-with-single-leader broadcast ("previous approaches" [3]).

    Machines are opaque nodes: binomial tree over machine leaders (one egress
    link each -- ignores Rule 3), then one local write per machine (Rule 1).
    """
    sched = Schedule("bcast_hier_seq", "broadcast", topo, m, root=root)
    payload = _pay(payloads, [("bcast", root)])
    M = topo.n_machines
    root_mach = topo.machine_of(root)
    leaders = {root_mach: root}
    covered = [root_mach]
    remaining = [j for j in range(M) if j != root_mach]
    while remaining:
        rnd = sched.new_round()
        n = min(len(covered), len(remaining))
        batch, remaining = remaining[:n], remaining[n:]
        for src_mach, dst_mach in zip(covered, batch):
            leader = next(iter(topo.procs_of(dst_mach)))
            rnd.add(Send(leaders[src_mach], leader, m, payload))
            leaders[dst_mach] = leader
        covered.extend(batch)
    # Leaders publish machine-wide: one Rule-1 write per machine on a
    # two-tier cluster, a tier-recursive fan-out on deeper hierarchies.
    _publish_all(
        sched, topo,
        [(leader, m, payload) for _, leader in sorted(leaders.items())],
    )
    return sched


def bcast_hier_par(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """The paper's broadcast: local write + degree-parallel egress.

    Once a machine's shared-memory group holds the value every co-located
    proc holds it (Rule 1 write), so a machine can seed up to ``degree`` new
    machines per round (Rule 3): on a two-tier cluster coverage multiplies
    by (degree+1) per global round ==> ceil(log_{d+1}(M)) global rounds.

    Tier-recursive form: every seeding Send (machine-level or across a
    machine's inner tiers) chains the landing tier-0 group's Rule-1 write
    into the same round; knowing procs not busy with Rule-3 egress seed
    still-uncovered shared-memory groups of their own machine across the
    inner tiers.  A two-tier topology reproduces the paper's schedule
    exactly (the whole machine is one tier-0 group, so machines are fully
    covered the round they are seeded).
    """
    sched = Schedule("bcast_hier_par", "broadcast", topo, m, root=root)
    payload = _pay(payloads, [("bcast", root)])
    d = min(topo.degree, topo.procs_per_machine)
    knows = {root}

    # Round 0: publish inside the root's shared-memory group so its procs
    # can fan out in parallel (Rule 1).
    peers = tuple(p for p in topo.inner_peers(root) if p != root)
    if peers:
        rnd = sched.new_round()
        rnd.add(LocalWrite(root, peers, m, payload))
        knows.update(peers)

    while len(knows) < topo.n_procs:
        rnd = sched.new_round()
        used_src: set = set()
        new_knows: set = set()
        by_mach: dict[int, list] = {}
        for p in sorted(knows):
            by_mach.setdefault(topo.machine_of(p), []).append(p)
        targets = [
            mach for mach in range(topo.n_machines) if mach not in by_mach
        ]

        def seed(src: int, dst: int) -> None:
            """Send + chained Rule-1 write covering dst's tier-0 group."""
            rnd.add(Send(src, dst, m, payload))
            used_src.add(src)
            new_knows.add(dst)
            lw = tuple(q for q in topo.inner_peers(dst) if q != dst)
            if lw:
                rnd.add(LocalWrite(dst, lw, m, payload))
                used_src.add(dst)
                new_knows.update(lw)

        # Rule 3: covered machines seed uncovered machines on up to d
        # parallel egress links each.
        ti = 0
        for mach, procs in sorted(by_mach.items()):
            for src in procs[:d]:
                if ti >= len(targets):
                    break
                seed(src, next(iter(topo.procs_of(targets[ti]))))
                ti += 1
            if ti >= len(targets):
                break

        # Inner tiers: remaining knowing procs seed uncovered shared-memory
        # groups within their own machine.
        for mach, procs in sorted(by_mach.items()):
            groups = sorted(
                {
                    topo.inner_group_of(p)
                    for p in topo.procs_of(mach)
                    if p not in knows
                }
            )
            for g in groups:
                leader = next(iter(topo.group_procs(1, g)))
                src = _nearest_free(topo, procs, used_src, leader)
                if src is None:
                    break
                seed(src, leader)
        knows |= new_knows
    return sched


# ======================================================================
# GATHER  (root ends with every proc's chunk; payloads concatenate)
# ======================================================================

def gather_flat_binomial(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """Inverse binomial tree to root, hierarchy-oblivious."""
    sched = Schedule("gather_flat_binomial", "gather", topo, m, root=root)
    P = topo.n_procs
    unrel = lambda r: (r + root) % P
    counts = {p: 1 for p in range(P)}
    know = {p: {p} for p in range(P)} if payloads else None
    k = 0
    while (1 << k) < P:
        rnd = sched.new_round()
        for r in range(1 << k, P, 1 << (k + 1)):
            src, dst = unrel(r), unrel(r - (1 << k))
            pay = _pay(payloads, know[src]) if payloads else EMPTY
            rnd.add(Send(src, dst, m * counts[src], pay))
            counts[dst] += counts[src]
            if payloads:
                know[dst] |= know[src]
        k += 1
    return sched


def _lockstep_local_combine(
    sched: Schedule,
    topo: ClusterTopology,
    heads: dict,
    counts: dict,
    know,
    m: float,
    payloads: bool,
    concat: bool,
) -> None:
    """Tree-combine each machine's procs onto its head, machines in lockstep.

    Rule 1 reads: each combine step is a local Send (clique read).  For
    ``concat`` collectives (gather) bytes grow with chunk counts; for
    reductions bytes stay m.
    """
    lives = {}
    for mach in range(topo.n_machines):
        head = heads[mach]
        lives[mach] = [head] + [p for p in topo.procs_of(mach) if p != head]
    while any(len(v) > 1 for v in lives.values()):
        rnd = sched.new_round()
        for mach, live in lives.items():
            if len(live) <= 1:
                continue
            half = (len(live) + 1) // 2
            for i in range(len(live) - half):
                src, dst = live[half + i], live[i]
                nb = m * counts[src] if concat else m
                pay = _pay(payloads, know[src]) if payloads else EMPTY
                rnd.add(Send(src, dst, nb, pay))
                counts[dst] += counts[src]
                if payloads:
                    know[dst] |= know[src]
            lives[mach] = live[:half]


def gather_hier_par(
    topo: ClusterTopology, m: float, root: int = 0, payloads: bool = True
) -> Schedule:
    """The paper's gather: clique-read local combine, then parallel ingress.

    Rule 1 says reads are NOT free: each machine tree-combines its procs'
    chunks over local clique edges (ceil(log2 c) local rounds), then machine
    buffers flow to the root machine, which ingests on up to ``degree`` links
    per round (Rule 3) into distinct procs, which the root finally reads.
    This schedule is *not* the inverse of the broadcast tree -- reproducing
    the paper's C2 asymmetry.
    """
    sched = Schedule("gather_hier_par", "gather", topo, m, root=root)
    c = topo.procs_per_machine
    M = topo.n_machines
    root_mach = topo.machine_of(root)
    d = min(topo.degree, c)

    counts = {p: 1 for p in range(topo.n_procs)}
    know = {p: {p} for p in range(topo.n_procs)} if payloads else None
    heads = {
        mach: (root if mach == root_mach else next(iter(topo.procs_of(mach))))
        for mach in range(M)
    }
    _lockstep_local_combine(sched, topo, heads, counts, know, m, payloads, concat=True)

    # Phase 2: machines ship combined buffers to the root machine.  Each
    # machine buffer is STRIPED across up to d ingress links landing on
    # distinct procs of the root machine (Rule 3 parallel ingress) -- this is
    # where gather stops being the inverse of broadcast: the root machine can
    # ingest on all links at once, but the root proc still has to *read*
    # every stripe (Rule 1).
    pending = [mach for mach in range(M) if mach != root_mach]
    recv_procs = [p for p in topo.procs_of(root_mach) if p != root] or [root]
    n_stripes = max(1, min(d, len(recv_procs)))
    ingress: list[tuple] = []
    if pending:
        # Rule 1 write: every remote head publishes its machine buffer so d
        # co-located procs can stripe it out in parallel (one shared round
        # on a two-tier cluster; tier-recursive distribution otherwise).
        if n_stripes > 1:
            _distribute(
                sched, topo,
                [
                    (
                        heads[mach],
                        [
                            p
                            for p in list(topo.procs_of(mach))[:n_stripes]
                            if p != heads[mach]
                        ],
                        m * counts[heads[mach]],
                        _pay(payloads, know[heads[mach]])
                        if payloads
                        else EMPTY,
                    )
                    for mach in pending
                ],
            )
        # One transfer round per remote machine: its buffer striped across
        # the root machine's ingress links (Rule 3).
        for mach in pending:
            src_procs = list(topo.procs_of(mach))[:n_stripes]
            chunks = (
                sorted(know[heads[mach]])
                if payloads
                else [None] * counts[heads[mach]]
            )
            per = math.ceil(len(chunks) / len(src_procs))
            rnd = sched.new_round()
            for k, src in enumerate(src_procs):
                stripe = chunks[k * per:(k + 1) * per]
                if not stripe:
                    continue
                dst = recv_procs[k % len(recv_procs)]
                pay = _pay(payloads, [ch for ch in stripe if ch is not None])
                rnd.add(Send(src, dst, m * len(stripe), pay))
                if payloads:
                    know[dst] |= set(pay)
                ingress.append((dst, len(stripe), pay))

    # Phase 3: root reads the ingress procs' buffers (clique reads; the
    # root's receive port admits one read per round).
    for dst, cnt, pay in ingress:
        if dst == root:
            continue
        rnd = sched.new_round()
        rnd.add(Send(dst, root, m * cnt, pay))
        counts[root] += cnt
        if payloads:
            know[root] |= set(pay)
    return sched


# ======================================================================
# ALL-GATHER
# ======================================================================

def allgather_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic ring all-gather: P-1 rounds of m bytes, hierarchy-oblivious."""
    sched = Schedule("allgather_flat_ring", "all_gather", topo, m)
    P = topo.n_procs
    for step in range(P - 1):
        rnd = sched.new_round()
        for p in range(P):
            chunk_id = (p - step) % P
            rnd.add(Send(p, (p + 1) % P, m, _pay(payloads, [chunk_id])))
    return sched


def allgather_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Two-tier all-gather: local clique all-gather, striped machine ring,
    local write.

    Phase 2 stripes each machine's consolidated c*m buffer across d egress
    procs: d parallel machine-level rings each carrying ~c*m/d per step
    (Rule 3).  Phase 3 publishes received stripes via shared-memory writes
    (Rule 1).
    """
    sched = Schedule("allgather_hier_par", "all_gather", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)
    P = topo.n_procs
    know = {p: {p} for p in range(P)} if payloads else None
    counts = {p: 1 for p in range(P)}

    # Phase 1: local all-gather over the clique.  Recursive doubling when c
    # is a power of two, ring otherwise.
    if c > 1 and (c & (c - 1)) == 0:
        step = 1
        while step < c:
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    j = i ^ step
                    if i < j:
                        p, q = procs[i], procs[j]
                        pp = _pay(payloads, know[p]) if payloads else EMPTY
                        pq = _pay(payloads, know[q]) if payloads else EMPTY
                        rnd.add(Send(p, q, m * counts[p], pp))
                        rnd.add(Send(q, p, m * counts[q], pq))
                        tot = counts[p] + counts[q]
                        counts[p] = counts[q] = tot
                        if payloads:
                            merged = know[p] | know[q]
                            know[p] = set(merged)
                            know[q] = set(merged)
            step <<= 1
    elif c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                moves = []
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    src_chunk = procs[(i - step) % c]
                    moves.append((p, q, src_chunk))
                    rnd.add(Send(p, q, m, _pay(payloads, [src_chunk])))
                for p, q, ch in moves:
                    counts[q] += 1
                    if payloads:
                        know[q].add(ch)

    if M > 1:
        # Phase 2: striped ring over machines.  Egress proc k of machine i
        # sends stripe k of the machine's buffer to proc k of machine i+1.
        stripe_chunks: dict[tuple[int, int], list] = {}
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            per = math.ceil(c / d)
            for k in range(d):
                stripe_chunks[(mach, k)] = procs[k * per:(k + 1) * per]
        carry = dict(stripe_chunks)
        for _ in range(M - 1):
            rnd = sched.new_round()
            new_carry = {}
            for mach in range(M):
                nxt = (mach + 1) % M
                src_procs = list(topo.procs_of(mach))[:d]
                dst_procs = list(topo.procs_of(nxt))[:d]
                for k in range(d):
                    chunks = carry[(mach, k)]
                    if not chunks:
                        new_carry[(nxt, k)] = []
                        continue
                    rnd.add(
                        Send(
                            src_procs[k],
                            dst_procs[k],
                            m * len(chunks),
                            _pay(payloads, chunks),
                        )
                    )
                    counts[dst_procs[k]] += len(chunks)
                    if payloads:
                        know[dst_procs[k]] |= set(chunks)
                    new_carry[(nxt, k)] = chunks
            carry = new_carry

        # Phase 3: every egress proc publishes everything it accumulated
        # (machine-wide: one write round on two tiers, recursive otherwise).
        items = []
        for mach in range(M):
            procs = list(topo.procs_of(mach))
            for k in range(d):
                w = procs[k]
                items.append(
                    (
                        w,
                        m * counts[w],
                        _pay(payloads, know[w]) if payloads else EMPTY,
                    )
                )
                if payloads:
                    for p in procs:
                        if p != w:
                            know[p] |= know[w]
        _publish_all(sched, topo, items)
    return sched


# ======================================================================
# ALL-REDUCE  (payload = contribution sets; message size fixed at m)
# ======================================================================

def allreduce_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic flat ring all-reduce: reduce-scatter then all-gather.

    2*(P-1) rounds of m/P bytes; ~2m bytes on the wire per proc, blind to
    which edges cross machines.
    """
    sched = Schedule("allreduce_flat_ring", "all_reduce", topo, m)
    P = topo.n_procs
    shard_m = m / P
    holdings = (
        [{s: {("rs", s, p)} for s in range(P)} for p in range(P)]
        if payloads
        else None
    )
    for phase in range(2):  # 0 = reduce-scatter, 1 = all-gather
        for step in range(P - 1):
            rnd = sched.new_round()
            moves = []
            for p in range(P):
                if phase == 0:
                    shard = (p - step) % P
                else:
                    shard = (p + 1 - step) % P
                if payloads:
                    pay = frozenset(holdings[p][shard])
                else:
                    pay = EMPTY
                moves.append((p, (p + 1) % P, shard, pay))
                rnd.add(Send(p, (p + 1) % P, shard_m, pay))
            if payloads:
                for p, q, shard, pay in moves:
                    holdings[q][shard] |= set(pay)
    return sched


def reducescatter_flat_ring(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic flat ring reduce-scatter: P-1 rounds of m/P bytes.

    The first half of ``allreduce_flat_ring``: proc p ends holding the
    fully reduced shard (p+1) % P.  Hierarchy-oblivious -- ring edges cross
    machine seams blind, so on multi-core clusters the simulator charges
    the shared-NIC serialization just like the flat all-reduce.
    """
    sched = Schedule("reducescatter_flat_ring", "reduce_scatter", topo, m)
    P = topo.n_procs
    shard_m = m / P
    holdings = (
        [{s: {("rs", s, p)} for s in range(P)} for p in range(P)]
        if payloads
        else None
    )
    for step in range(P - 1):
        rnd = sched.new_round()
        moves = []
        for p in range(P):
            shard = (p - step) % P
            pay = frozenset(holdings[p][shard]) if payloads else EMPTY
            moves.append((p, (p + 1) % P, shard, pay))
            rnd.add(Send(p, (p + 1) % P, shard_m, pay))
        if payloads:
            for p, q, shard, pay in moves:
                holdings[q][shard] |= set(pay)
    return sched


def reducescatter_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Hierarchy-aware reduce-scatter (Rules 1+3; bandwidth-optimal).

    The first half of ``allreduce_hier_par_bw``, tier-recursive: one
    lockstep ring reduce-scatter stage per tier, innermost outwards.  At
    tier l every proc belongs to one of the parallel rings over its
    level-l siblings, working on a 1/group_size(l) slice of the vector --
    so ALL procs drive their machine's egress links at once when the
    outermost stage runs (Rule 3 as a limit).  On a two-tier cluster this
    is exactly the paper's pair: (c-1) local rounds of m/c, then (M-1)
    global rounds of m/(c*M) sub-shards.

    Every proc ends with 1/P of the fully reduced vector; global bytes per
    machine m*(M-1)/M -- half an all-reduce, the bandwidth-optimal exchange
    the bucketed gradient sync is built on.
    """
    sched = Schedule("reducescatter_hier_par", "reduce_scatter", topo, m)
    c0 = topo.fanout[0]
    P = topo.n_procs
    holdings = (
        [
            {s: {("lrs", topo.inner_group_of(p), s, p % c0)} for s in range(c0)}
            for p in range(P)
        ]
        if payloads
        else None
    )
    for level in range(topo.n_tiers):
        _ring_rs_stage(sched, topo, level, m, payloads, holdings=holdings)
    return sched


def allreduce_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """The paper's all-reduce on a two-tier cluster.

    Phase 1 (Rule 1 reads):   local tree-reduce within each machine.
    Phase 2 (Rule 1 write):   head publishes so d egress procs hold the
                              machine vector, striped m/d each.
    Phase 3 (Rule 3):         inter-machine reduce-scatter + all-gather ring
                              run independently per stripe -- all d global
                              links busy every round.
    Phase 4 (Rule 1 write):   egress procs publish the reduced result.

    Global bytes per machine ~ 2*m*(M-1)/M (bandwidth-optimal), wall-clock
    divided by d.
    """
    sched = Schedule("allreduce_hier_par", "all_reduce", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)
    counts = {p: 1 for p in range(topo.n_procs)}
    know = (
        {p: {("ar", p)} for p in range(topo.n_procs)} if payloads else None
    )
    heads = {mach: next(iter(topo.procs_of(mach))) for mach in range(M)}
    _lockstep_local_combine(sched, topo, heads, counts, know, m, payloads, concat=False)

    if M == 1:
        head = heads[0]
        pay = _pay(payloads, know[head]) if payloads else EMPTY
        _publish_all(sched, topo, [(head, m, pay)])
        return sched

    # Phase 2: stripe distribution by shared-memory write (tier-recursive
    # on deeper hierarchies -- egress procs may sit in other tier-0 groups).
    if d > 1:
        items = []
        for mach in range(M):
            head = heads[mach]
            egress = [p for p in list(topo.procs_of(mach))[:d] if p != head]
            pay = _pay(payloads, know[head]) if payloads else EMPTY
            items.append((head, egress, m, pay))
            if payloads:
                for p in egress:
                    know[p] |= know[head]
        _distribute(sched, topo, items)

    # Phase 3: striped machine-level ring reduce-scatter + all-gather.
    stripe_m = m / d
    shard_m = stripe_m / M
    for phase in ("rs", "ag"):
        for step in range(M - 1):
            rnd = sched.new_round()
            for mach in range(M):
                nxt = (mach + 1) % M
                for k in range(d):
                    src = list(topo.procs_of(mach))[k]
                    dst = list(topo.procs_of(nxt))[k]
                    rnd.add(
                        Send(
                            src,
                            dst,
                            shard_m,
                            _pay(payloads, [("arstripe", phase, step, mach, k)]),
                        )
                    )

    # Phase 4: publish (machine-wide fan-out per egress proc).
    _publish_all(
        sched, topo,
        [
            (list(topo.procs_of(mach))[k], stripe_m,
             _pay(payloads, [("arfinal", k)]))
            for mach in range(M)
            for k in range(d)
        ],
    )
    return sched


def allreduce_hier_par_bw(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Bandwidth-optimal two-tier all-reduce (large-message regime).

    Found *with* the paper's cost model (see EXPERIMENTS.md): the tree-based
    ``allreduce_hier_par`` moves the full vector log2(c) times inside each
    machine, so at large m the local tier dominates.  This variant:

    Phase 1: intra-machine ring reduce-scatter -- (c-1) local rounds of m/c;
             proc i of each machine ends holding reduced local shard i.
    Phase 2: every proc ring-exchanges ITS shard across machines
             (reduce-scatter + all-gather over M, sub-shards m/(c*M)).
             All c procs hit the NICs at once; the simulator charges the
             ceil(c/degree) NIC serialization (Rule 3 as a limit), which
             still beats funnelling through one leader by ~degree.
    Phase 3: intra-machine ring all-gather -- (c-1) local rounds of m/c.

    Local bytes/proc ~ 2m, global bytes/machine ~ 2m(M-1)/M: both optimal.
    """
    sched = Schedule("allreduce_hier_par_bw", "all_reduce", topo, m)
    c0 = topo.fanout[0]
    P = topo.n_procs
    holdings = (
        [
            {s: {("lrs", topo.inner_group_of(p), s, p % c0)} for s in range(c0)}
            for p in range(P)
        ]
        if payloads
        else None
    )
    # Ring reduce-scatter per tier, innermost outwards; then the mirror-image
    # ring all-gather back in.  Two tiers: (c-1) local rounds of m/c,
    # (M-1)+(M-1) global rounds of m/(c*M), (c-1) local rounds of m/c --
    # exactly the paper's bandwidth-optimal pair of phases.
    for level in range(topo.n_tiers):
        _ring_rs_stage(sched, topo, level, m, payloads, holdings=holdings)
    for level in range(topo.n_tiers - 1, -1, -1):
        _ring_ag_stage(sched, topo, level, m, payloads, token="fin")
    return sched


# ======================================================================
# ALL-TO-ALL  (chunk (s, d) of m bytes must travel from proc s to proc d)
# ======================================================================

def alltoall_flat_pairwise(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Classic rotation all-to-all: P-1 rounds, proc p sends to p+r.

    Every (s,d) chunk crosses the network individually.  When a machine's c
    procs all send globally in one round they oversubscribe its ``degree``
    shared NICs; the simulator charges the ceil(c/degree) serialization --
    exactly the hidden cost the paper says flat algorithms suffer on
    multi-core clusters.
    """
    sched = Schedule("alltoall_flat_pairwise", "all_to_all", topo, m)
    P = topo.n_procs
    for r in range(1, P):
        rnd = sched.new_round()
        for p in range(P):
            q = (p + r) % P
            rnd.add(Send(p, q, m, _pay(payloads, [("a2a", p, q)])))
    return sched


def alltoall_hier_par(
    topo: ClusterTopology, m: float, payloads: bool = True
) -> Schedule:
    """Kumar-style [3] two-tier all-to-all under the paper's model.

    Phase 1: intra-machine consolidation -- clique reads redistribute traffic
             so each of the d egress procs holds the outgoing stripes.
    Phase 2: machine-pair exchange, (M-1) rounds; round r machine i sends its
             consolidated c^2*m buffer for machine i+r striped over d egress
             procs (Rule 3).
    Phase 3: receiving procs publish to destinations by local writes (Rule 1).
    """
    sched = Schedule("alltoall_hier_par", "all_to_all", topo, m)
    c = topo.procs_per_machine
    M = topo.n_machines
    d = min(topo.degree, c)

    # Phase 1: local redistribution (ring over the clique, c-1 local rounds;
    # each proc forwards the bundle destined to egress proc p+1: M*m bytes).
    if c > 1:
        for step in range(c - 1):
            rnd = sched.new_round()
            for mach in range(M):
                procs = list(topo.procs_of(mach))
                for i in range(c):
                    p, q = procs[i], procs[(i + 1) % c]
                    rnd.add(
                        Send(
                            p, q, m * M, _pay(payloads, [("a2a_loc", p, q, step)])
                        )
                    )

    # Phase 2: machine-pair exchanges with striped egress.
    if M > 1:
        consolidated = c * c * m
        stripe = consolidated / d
        for r in range(1, M):
            rnd = sched.new_round()
            for mach in range(M):
                dst_mach = (mach + r) % M
                src_procs = list(topo.procs_of(mach))[:d]
                dst_procs = list(topo.procs_of(dst_mach))[:d]
                for k in range(d):
                    rnd.add(
                        Send(
                            src_procs[k],
                            dst_procs[k],
                            stripe,
                            _pay(payloads, [("a2a_glob", mach, dst_mach, k)]),
                        )
                    )

        # Phase 3: publish received stripes (Rule 1 writes, machine-wide).
        _publish_all(
            sched, topo,
            [
                (list(topo.procs_of(mach))[k], c * m,
                 _pay(payloads, [("a2a_pub", mach, k)]))
                for mach in range(M)
                for k in range(d)
            ],
        )
    return sched


# ----------------------------------------------------------------------
# Registry bridge
# ----------------------------------------------------------------------
#
# The generator functions above are *bound* to strategies (and to their
# runnable twins) in the ``repro.comm`` registry -- the single source of
# truth.  ``GENERATORS`` survives as a derived, read-only view for legacy
# callers; it is resolved lazily (PEP 562) to keep this module importable
# without pulling in jax through ``repro.comm.impls``.


def build(
    topo: ClusterTopology,
    collective: str,
    strategy: str,
    m: float,
    root: int = 0,
    payloads: bool = True,
) -> Schedule:
    """Build the schedule for a registered (collective, strategy) pair."""
    from repro import comm

    return comm.get_spec(collective, strategy).build_schedule(
        topo, m, root=root, payloads=payloads
    )


def __getattr__(name: str):
    if name == "GENERATORS":
        from repro import comm

        return comm.generators_view()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
