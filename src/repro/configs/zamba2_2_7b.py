"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 -- Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

The shared attention+MLP block (one set of params) is applied every 6 mamba
layers (54 / 6 = 9 applications).  For ``long_500k`` the launcher overrides
``sliding_window=4096`` so the shared block's KV stays bounded (the paper-
assigned sub-quadratic path).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    attn_every=6,
    rope_theta=10000.0,
    tie_embeddings=True,
)
