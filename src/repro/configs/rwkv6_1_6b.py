"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 -- Finch: data-dependent decay.  [arXiv:2404.05892; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # wkv heads = d_model / ssm_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    tie_embeddings=True,
)
