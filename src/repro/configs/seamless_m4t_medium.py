"""seamless-m4t-medium [audio]: 12L d_model=1024 16H (GQA kv=16) d_ff=4096
vocab=256206 -- enc-dec, multimodal.  [arXiv:2308.11596; hf]

Backbone only: 12 encoder + 12 decoder layers; the speech frontend is a
stub; ``input_specs`` provides precomputed frame embeddings [B, S, D].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    rope_theta=10000.0,
    tie_embeddings=True,
)
