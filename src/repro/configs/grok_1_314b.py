"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]

Grok-style attention logit soft-capping (tanh at 30).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    n_experts=8,
    n_experts_per_tok=2,
    moe_d_ff=32768,
    attn_logit_softcap=30.0,
    rope_theta=10000.0,
)
