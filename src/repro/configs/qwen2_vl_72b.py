"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 -- M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

Backbone only: the vision frontend is a stub; ``input_specs`` provides
precomputed patch embeddings and [3, B, S] M-RoPE position grids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    head_dim=128,
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
)
