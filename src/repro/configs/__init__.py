"""Assigned-architecture configs (exact dims from the assignment) + shapes.

Every entry is selectable via ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

ARCH_IDS = [
    "llama3_2_3b",
    "command_r_35b",
    "granite_3_8b",
    "llama3_2_1b",
    "zamba2_2_7b",
    "qwen2_vl_72b",
    "seamless_m4t_medium",
    "grok_1_314b",
    "qwen2_moe_a2_7b",
    "rwkv6_1_6b",
]

# canonical external ids (assignment spelling) -> module name
ALIASES = {
    "llama3.2-3b": "llama3_2_3b",
    "command-r-35b": "command_r_35b",
    "granite-3-8b": "granite_3_8b",
    "llama3.2-1b": "llama3_2_1b",
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "grok-1-314b": "grok_1_314b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "rwkv6-1.6b": "rwkv6_1_6b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether an (arch, shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k decode skipped (see DESIGN.md)"
    return True, ""


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            yield arch, cfg, shape, ok, why
