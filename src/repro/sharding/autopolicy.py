"""Model-driven sharding policy selection -- the paper's cost model closing
the loop on the framework's own parallelism choices.

For a training cell, the dominant communication volumes of the two policy
candidates are:

  * ``tp16``  (TP over 'model', FSDP over 'data'):
      per layer, per microbatch: 4 Megatron activation reduces of
      [B_local, S, D] (fwd attn+mlp, bwd column-parallel inputs)
      + FSDP weight gathers + grad reduce-scatters.
  * ``dp256`` (fold_model: both axes data-parallel, params replicated over
      'model'):
      no activation reduces; FSDP gathers/grad-RS only, but over 16x more
      DP replicas of the vocab-unsharded logits (memory, not wire) and the
      full gradient reduce spans both axes.

This module prices both with the same two-tier constants the collective
planner uses and picks the cheaper; EXPERIMENTS.md SPerf-1 validates the
decision against compiled HLO for llama3.2-1b (predicted 6.7x, measured
6.7x wire reduction).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.topology import V5E_ICI_BW
from repro.models.config import ModelConfig

from .rules import ShardingPolicy

BYTES = 2  # bf16 transport (lm._cast_big_params)


@dataclass(frozen=True)
class PolicyEstimate:
    name: str
    act_reduce_bytes: float
    weight_gather_bytes: float
    grad_sync_bytes: float

    @property
    def total(self) -> float:
        return self.act_reduce_bytes + self.weight_gather_bytes + self.grad_sync_bytes

    @property
    def t_collective(self) -> float:
        return self.total / V5E_ICI_BW


def estimate(cfg: ModelConfig, global_batch: int, seq: int, accum: int,
             data: int = 16, model: int = 16) -> dict:
    """Per-device wire bytes per step for both policies."""
    P = cfg.param_count()
    tokens_dev_tp = global_batch * seq // data          # batch over data only
    tokens_dev_dp = global_batch * seq // (data * model)
    L_eff = cfg.n_layers + (cfg.n_enc_layers or 0)
    D = cfg.d_model

    # --- tp16 ---
    # 4 activation all-reduces per layer per microbatch over 'model'
    # (wire ~ 2x payload per ring participant)
    act = 4 * L_eff * accum * (tokens_dev_tp // accum) * D * BYTES * 2
    # FSDP gathers: params (already /model from TP) gathered over 'data',
    # twice per microbatch (fwd + remat bwd)
    wg = 2 * accum * (P / model) * BYTES
    # grad reduce-scatter over 'data' per microbatch
    gs = accum * (P / model) * BYTES
    tp16 = PolicyEstimate("tp16", act, wg, gs)

    # --- dp256 ---
    act2 = 0.0
    wg2 = 2 * accum * P * BYTES / model / data * (data * model - 1) / 1  # ~P*2
    # simpler upper bound: params fully gathered from 256-way FSDP
    wg2 = 2 * accum * P * BYTES
    gs2 = accum * P * BYTES
    dp256 = PolicyEstimate("dp256", act2, wg2, gs2)
    return {"tp16": tp16, "dp256": dp256}


def choose_policy(cfg: ModelConfig, global_batch: int, seq: int,
                  accum: int = 1) -> tuple:
    """-> (ShardingPolicy, dict of estimates)."""
    est = estimate(cfg, global_batch, seq, accum)
    fold = est["dp256"].total < est["tp16"].total
    # memory guard: dp256 replicates params over 'model' -- only fold when
    # f32 params + 2 moments fit comfortably in HBM/16-way sharding
    state_bytes = cfg.param_count() * 12 / 16
    if state_bytes > 8e9:
        fold = False
    return ShardingPolicy(fold_model=fold,
                          shard_vocab=not fold and cfg.padded_vocab % 16 == 0), est
