"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

Each pipeline stage lives on one mesh slice; microbatches stream through
with ``lax.ppermute`` moving activations stage-to-stage.  In the paper's
model a stage hand-off is ONE point-to-point transfer per round -- the
cheapest collective there is -- which is why PP is attractive across slow
tiers; our planner's cost model (see DESIGN.md) still prefers
hierarchical-DP over inter-pod PP for the assigned model sizes because the
pipeline bubble at global-batch/256 microbatches dominates, but the
machinery is here and tested.

``pipeline_apply`` is deliberately minimal (inference/forward): it
demonstrates and tests the communication pattern; a full PP trainer would
wrap it with the usual 1F1B schedule.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def pipeline_apply(stage_fn, stage_weights, microbatches, mesh, n_stage: int):
    """Run ``n_stage`` sequential stages over microbatches, pipelined.

    stage_fn:        (w, x) -> y, same x/y shape.
    stage_weights:   [n_stage, ...] stacked per-stage params.
    microbatches:    [n_micro, ...] inputs.
    mesh:            1-D mesh with axis 'pipe' of size n_stage.

    Returns [n_micro, ...] outputs, equal to sequential application.
    """
    n_micro = microbatches.shape[0]
    steps = n_micro + n_stage - 1

    def body(w, xs):
        w = w[0]                     # this stage's weights
        idx = lax.axis_index("pipe")

        def step(buf, t):
            x0 = lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            x_in = jnp.where(idx == 0, x0, buf)
            y = stage_fn(w, x_in)
            y_next = lax.ppermute(
                y, "pipe", [(i, (i + 1) % n_stage) for i in range(n_stage)]
            )
            return y_next, y

        _, ys = lax.scan(step, jnp.zeros_like(xs[0]), jnp.arange(steps))
        # the final stage emits microbatch t-(n_stage-1) at time t
        return ys[n_stage - 1:]

    res = shard_map(
        body,
        mesh=mesh,
        in_specs=(P("pipe"), P()),
        out_specs=P("pipe"),
        check_vma=False,   # scan carry becomes device-varying via ppermute
    )(stage_weights, microbatches)
    # stacked [n_stage * n_micro, ...]; the last stage's block is the answer
    res = res.reshape(n_stage, n_micro, *res.shape[1:])
    return res[-1]
