"""shard_map compatibility across jax versions.

Newer jax exposes ``jax.shard_map`` with a ``check_vma`` flag; the pinned
version only has ``jax.experimental.shard_map.shard_map`` with the older
``check_rep`` spelling of the same knob.  Call sites use this wrapper so
they read like the modern API either way.
"""

from __future__ import annotations

import jax


def axis_size(name) -> int:
    """Static mesh-axis size inside a shard_map region.

    ``lax.axis_size`` only exists on newer jax; ``lax.psum`` of a Python
    scalar constant-folds to the axis size (a plain int) on the pinned
    version.
    """
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
