"""shard_map compatibility across jax versions.

The pinned jax ships ``jax.experimental.shard_map.shard_map`` with the
``check_rep`` knob; that is the API this repo targets.  Some newer
releases promote it to a top-level ``jax.shard_map`` whose equivalent
knob is spelled ``check_vma``, so this wrapper probes for the top-level
name first and otherwise uses the experimental module.  Call sites read
like the modern spelling either way.
"""

from __future__ import annotations

import jax


def axis_size(name) -> int:
    """Static mesh-axis size inside a shard_map region.

    ``lax.axis_size`` only exists on newer jax; ``lax.psum`` of a Python
    scalar constant-folds to the axis size (a plain int) on the pinned
    version.
    """
    from jax import lax

    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )
