"""Sharding policy: parameter / activation / cache PartitionSpecs.

Axis roles on the production mesh (see launch/mesh.py):

  * ``pod``   -- data-parallel across pods; the paper's *global edge* tier.
                 Kept out of GSPMD (manual shard_map axis) in the planner-
                 driven train step so inter-pod traffic is always explicit.
  * ``data``  -- intra-pod data parallelism for activations + FSDP (ZeRO-3)
                 sharding for parameters/optimizer state.
  * ``model`` -- tensor parallelism (heads / mlp hidden / vocab / d_inner).

Rules are matched by parameter-tree path suffixes.  The policy object lets
the perf loop flip individual decisions (e.g. FSDP off, vocab replicated)
without touching model code.
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class ShardingPolicy:
    data_axis: str = "data"
    model_axis: str = "model"
    fsdp: bool = True            # shard params/opt-state over data_axis
    shard_vocab: bool = True     # TP the embedding/unembedding vocab dim
    scan_layers: bool = True
    # fold_model: no tensor parallelism -- the 'model' mesh axis carries
    # extra data parallelism instead (params replicated across it, batch
    # sharded over both axes).  The planner-recommended policy for models
    # whose per-layer TP reduces dominate the roofline (small archs).
    fold_model: bool = False

    @property
    def fsdp_axis(self):
        return self.data_axis if self.fsdp else None

    @property
    def tp_axis(self):
        return None if self.fold_model else self.model_axis

    @property
    def batch_axes(self) -> tuple:
        return (self.data_axis, self.model_axis) if self.fold_model else (
            self.data_axis,)


# (regex on "/".join(path), spec builder) -- first match wins.
# L = leading stacked-layer dim (present when scanned); specs below are for
# the stacked layout and are trimmed when the leaf has fewer dims.
def _param_rules(pol: ShardingPolicy, cfg: ModelConfig):
    dp, tp = pol.fsdp_axis, pol.tp_axis
    vocab_tp = tp if pol.shard_vocab else None
    return [
        # embeddings
        (r"embed/tok$", P(vocab_tp, dp)),
        (r"embed/unembed$", P(dp, vocab_tp)),
        # attention
        (r"attn/wq$", P(None, dp, tp)),
        (r"attn/wk$", P(None, dp, tp)),
        (r"attn/wv$", P(None, dp, tp)),
        (r"attn/wo$", P(None, tp, dp)),
        (r"xattn/wq$", P(None, dp, tp)),
        (r"xattn/wk$", P(None, dp, tp)),
        (r"xattn/wv$", P(None, dp, tp)),
        (r"xattn/wo$", P(None, tp, dp)),
        # dense mlp
        (r"mlp/w_gate$", P(None, dp, tp)),
        (r"mlp/w_up$", P(None, dp, tp)),
        (r"mlp/w_down$", P(None, tp, dp)),
        # moe: experts replicated on E, expert-hidden sharded over tp,
        # d_model over fsdp
        (r"moe/router$", P(None, dp, None)),
        (r"moe/w_gate$", P(None, None, dp, tp)),
        (r"moe/w_up$", P(None, None, dp, tp)),
        (r"moe/w_down$", P(None, None, tp, dp)),
        (r"moe/shared/w_gate$", P(None, dp, tp)),
        (r"moe/shared/w_up$", P(None, dp, tp)),
        (r"moe/shared/w_down$", P(None, tp, dp)),
        # mamba2
        (r"mamba/wz$", P(None, dp, tp)),
        (r"mamba/wx$", P(None, dp, tp)),
        (r"mamba/wB$", P(None, dp, None)),
        (r"mamba/wC$", P(None, dp, None)),
        (r"mamba/wdt$", P(None, dp, tp)),
        (r"mamba/w_out$", P(None, tp, dp)),
        (r"mamba/conv_w$", P(None, None, tp)),
        (r"mamba/(A_log|D|dt_bias)$", P(None, tp)),
        # rwkv6
        (r"rwkv/w(r|k|v|g)$", P(None, dp, tp)),
        (r"rwkv/wo$", P(None, tp, dp)),
        (r"rwkv/w_lora_a$", P(None, dp, None)),
        (r"rwkv/w_lora_b$", P(None, None, tp)),
        (r"rwkv/(w_base|u)$", P(None, tp)),
        (r"rwkv/ck$", P(None, dp, tp)),
        (r"rwkv/cv$", P(None, tp, dp)),
        (r"rwkv/cr$", P(None, dp, tp)),
        (r"rwkv/(mu|c_mu)$", P(None, None, None)),
        # norms and anything 1-dim: replicate
        (r"(norm|final_norm)", P()),
        (r".*", P()),
    ]


def _trim(spec: P, ndim: int, stacked: bool) -> P:
    """Fit a stacked-layout spec to the actual leaf rank."""
    parts = list(spec)
    if not stacked and parts and len(parts) > ndim:
        parts = parts[1:]          # drop the L dim entry
    if len(parts) > ndim:
        parts = parts[-ndim:]
    while len(parts) < ndim:
        parts = parts + [None]
    return P(*parts)


def _path_to_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def spec_for_str(pathstr: str, leaf, rules) -> P:
    stacked = pathstr.split("/")[0] in ("blocks", "enc_blocks")
    for pat, spec in rules:
        if re.search(pat, pathstr):
            return _trim(spec, leaf.ndim, stacked)
    return P()


def param_specs(cfg: ModelConfig, params_tree, pol: ShardingPolicy):
    """PartitionSpec pytree mirroring ``params_tree`` (arrays or ShapeDtype)."""
    rules = _param_rules(pol, cfg)
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for_str(_path_to_str(path), leaf, rules),
        params_tree,
    )


# ----------------------------------------------------------------------
# Activations / batch / cache
# ----------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, pol: ShardingPolicy, pod_axis: str | None = None):
    """Specs for the training batch dict {tokens, labels[, embeds, mrope]}."""
    ba = pol.batch_axes
    b = (pod_axis, *ba) if pod_axis else (ba if len(ba) > 1 else ba[0])
    specs = {"tokens": P(b, None), "labels": P(b, None)}
    if cfg.family in ("vlm",):
        specs["embeds"] = P(b, None, None)
        specs["positions"] = P(None, b, None)
    if cfg.family == "encdec":
        specs["enc_embeds"] = P(b, None, None)
    return specs


def cache_specs(cfg: ModelConfig, pol: ShardingPolicy, batch: int):
    """Decode-cache specs.

    KV heads shard over 'model' when divisible; otherwise the *sequence* dim
    of the cache shards over 'model' (sequence-parallel KV: softmax over a
    sharded axis lowers to the reduce the paper's model prices as local).
    The batch dim shards over 'data' when divisible.
    """
    tp, dp = pol.model_axis, pol.data_axis
    # mesh axis sizes are fixed at 16 for the production mesh; divisibility
    # checks happen against the actual mesh in the launchers.
    def kv_spec(n_heads_div: bool, batch_div: bool):
        b = dp if batch_div else None
        if n_heads_div:
            return P(None, b, None, tp, None)
        return P(None, b, tp, None, None)

    return {"kv_spec_builder": kv_spec}


def named(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
