"""Ring attention: sequence-parallel exact attention via ppermute.

Context parallelism for long prefill: Q, K, V are sharded over the sequence
dim across a mesh axis; K/V blocks rotate around the ring while each shard
maintains flash-style online-softmax state.  After n_shards steps every
query has attended to every key exactly once.

In the paper's model a ring hand-off is ONE point-to-point transfer per
round -- the cheapest collective there is -- and all links run concurrently
(Rule 3), which is why sequence parallelism is the planner's preferred way
to scale prefill beyond a pod: the per-step payload (2*S_local*Hkv*Dh) is
independent of the number of shards.

Forward-only (prefill); verified against full attention on 8 fake devices.
"""

from __future__ import annotations

import functools
import math

import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .compat import axis_size, shard_map

NEG_INF = -1e30


def _block_update(q, k, v, m, l, acc, qpos, kpos, scale, causal):
    """One online-softmax update of (m, l, acc) against a K/V block.

    q: [B, Sq, Hkv, G, Dh]; k/v: [B, Sk, Hkv, Dh]; positions global."""
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(logits - m_new)
    if causal:
        p = jnp.where(mask[None, None, None], p, 0.0)
    alpha = jnp.exp(m - m_new)
    l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * alpha[..., 0][..., None] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return m_new, l_new, acc_new


def _ring_body(q, k, v, axis_name: str, causal: bool):
    """Per-shard body (runs inside shard_map; seq dim sharded over axis)."""
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    n = axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    scale = 1.0 / math.sqrt(Dh)
    qh = q.reshape(B, S, Hkv, G, Dh)
    qpos = idx * S + jnp.arange(S)

    perm = [(j, (j + 1) % n) for j in range(n)]

    def step(carry, i):
        m, l, acc, k_cur, v_cur = carry
        src = (idx - i) % n                      # whose K/V we hold now
        kpos = src * S + jnp.arange(S)
        m, l, acc = _block_update(
            qh, k_cur.astype(qh.dtype), v_cur, m, l, acc, qpos, kpos,
            scale, causal,
        )
        k_nxt = lax.ppermute(k_cur, axis_name, perm)
        v_nxt = lax.ppermute(v_cur, axis_name, perm)
        return (m, l, acc, k_nxt, v_nxt), None

    m0 = jnp.full((B, Hkv, G, S, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, S, 1), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, S, Dh), jnp.float32)
    (m, l, acc, _, _), _ = lax.scan(step, (m0, l0, a0, k, v), jnp.arange(n))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l).transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dh)
    return out.astype(q.dtype)


def ring_attention(q, k, v, mesh, axis_name: str = "data",
                   causal: bool = True):
    """q: [B, S, H, Dh]; k/v: [B, S, Hkv, Dh], S sharded over ``axis_name``.

    Exact attention over the full (global) sequence; returns [B, S, H, Dh]
    with the same sequence sharding.
    """
    spec = P(None, axis_name, None, None)
    f = shard_map(
        functools.partial(_ring_body, axis_name=axis_name, causal=causal),
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=spec,
        check_vma=False,
    )
    return f(q, k, v)
