"""Batched generation engine over the unified LM.

Wraps prefill + decode with sampling, stop handling, and jitted steps with
donated caches (no per-token cache copies).  The decode_32k / long_500k
dry-run cells lower exactly this ``decode_step``.

``generate`` measures every decode step individually (one device sync per
step -- the measurement serving latency reporting actually requires) and
supports stop-token early exit, so a live run emits the same per-step
p50/p99 metrics the discrete-event simulator (``repro.sim``) produces for
the simulated cluster.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


def _percentile(values, q: float) -> float:
    """Nearest-rank-interpolated percentile, NaN on empty (mirrors
    ``repro.sim.serving.percentile``; kept local so serve never imports
    the simulator)."""
    if not values:
        return float("nan")
    xs = sorted(values)
    if len(xs) == 1:
        return float(xs[0])
    k = (q / 100.0) * (len(xs) - 1)
    lo = int(k)
    hi = min(lo + 1, len(xs) - 1)
    return float(xs[lo] + (xs[hi] - xs[lo]) * (k - lo))


@dataclass
class GenerationResult:
    tokens: jax.Array            # [B, steps]
    prefill_s: float
    decode_s: float
    steps: int                   # steps actually run (<= gen_len on stop)
    step_latencies_s: list = field(default_factory=list)  # per decode step
    stopped_early: bool = False

    @property
    def decode_tok_s(self) -> float:
        B = self.tokens.shape[0]
        return B * max(self.steps - 1, 1) / max(self.decode_s, 1e-9)

    @property
    def step_p50_s(self) -> float:
        return _percentile(self.step_latencies_s, 50)

    @property
    def step_p99_s(self) -> float:
        return _percentile(self.step_latencies_s, 99)


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # branch OUTSIDE the lambda: the seed nested the conditional in the
        # lambda body, producing a 4-arg prefill that broke every
        # decoder-only call
        if cfg.family == "encdec":
            self._prefill = jax.jit(
                lambda p, t, c, enc: lm.prefill(p, cfg, t, c, enc_embeds=enc)
            )
        else:
            self._prefill = jax.jit(
                lambda p, t, c: lm.prefill(p, cfg, t, c)
            )
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c),
            donate_argnums=(2,),
        )

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, -1)

    def generate(self, prompts: jax.Array, gen_len: int,
                 enc_embeds=None, stop_tokens=(),
                 pad_token: int = 0) -> GenerationResult:
        """Generate up to ``gen_len`` tokens per sequence.

        stop_tokens:  token ids that finish a sequence.  A finished
                      sequence keeps its slot (continuous batching at this
                      granularity is the simulator's job) but emits
                      ``pad_token`` from the next step on; decoding exits
                      as soon as EVERY sequence has stopped, so short
                      completions are not billed the full ``gen_len``.
        """
        B, S = prompts.shape
        cache = lm.init_cache(
            self.cfg, B, min(S + gen_len, self.max_len),
            enc_len=enc_embeds.shape[1] if enc_embeds is not None else S,
        )
        stop = (
            jnp.asarray(sorted(stop_tokens), jnp.int32)
            if stop_tokens else None
        )
        t0 = time.perf_counter()
        if self.cfg.family == "encdec":
            logits, cache = self._prefill(self.params, prompts, cache, enc_embeds)
        else:
            logits, cache = self._prefill(self.params, prompts, cache)
        logits.block_until_ready()
        t_pf = time.perf_counter() - t0

        tok = self._sample(logits)
        done = (
            jnp.isin(tok, stop) if stop is not None
            else jnp.zeros((B,), bool)
        )
        out = [tok]
        step_latencies: list[float] = []
        stopped_early = False
        t0 = time.perf_counter()
        for _ in range(gen_len - 1):
            if stop is not None and bool(done.all()):
                stopped_early = True
                break
            ts = time.perf_counter()
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)
            tok = jnp.where(done, pad_token, tok)
            tok.block_until_ready()
            step_latencies.append(time.perf_counter() - ts)
            out.append(tok)
            if stop is not None:
                done = done | jnp.isin(tok, stop)
        jax.block_until_ready(out[-1])
        t_dec = time.perf_counter() - t0
        return GenerationResult(
            tokens=jnp.stack(out, 1), prefill_s=t_pf, decode_s=t_dec,
            steps=len(out), step_latencies_s=step_latencies,
            stopped_early=stopped_early,
        )
