"""Batched generation engine over the unified LM.

Wraps prefill + decode with sampling, stop handling, and jitted steps with
donated caches (no per-token cache copies).  The decode_32k / long_500k
dry-run cells lower exactly this ``decode_step``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclass
class GenerationResult:
    tokens: jax.Array            # [B, gen_len]
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tok_s(self) -> float:
        B = self.tokens.shape[0]
        return B * max(self.steps - 1, 1) / max(self.decode_s, 1e-9)


class Engine:
    def __init__(self, cfg: ModelConfig, params, max_len: int,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, c, enc: lm.prefill(p, cfg, t, c, enc_embeds=enc)
            if cfg.family == "encdec"
            else lm.prefill(p, cfg, t, c),
            static_argnames=(),
        )
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c),
            donate_argnums=(2,),
        )

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / self.temperature, -1)

    def generate(self, prompts: jax.Array, gen_len: int,
                 enc_embeds=None) -> GenerationResult:
        B, S = prompts.shape
        cache = lm.init_cache(
            self.cfg, B, min(S + gen_len, self.max_len),
            enc_len=enc_embeds.shape[1] if enc_embeds is not None else S,
        )
        t0 = time.perf_counter()
        if self.cfg.family == "encdec":
            logits, cache = self._prefill(self.params, prompts, cache, enc_embeds)
        else:
            logits, cache = self._prefill(self.params, prompts, cache)
        logits.block_until_ready()
        t_pf = time.perf_counter() - t0

        tok = self._sample(logits)
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(gen_len - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)
            out.append(tok)
        jax.block_until_ready(out[-1])
        t_dec = time.perf_counter() - t0
        return GenerationResult(
            tokens=jnp.stack(out, 1), prefill_s=t_pf, decode_s=t_dec,
            steps=gen_len,
        )
