"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state mirrors parameter sharding (FSDP over 'data'): with ZeRO-3
each chip holds 1/|data| of m and v, and the planner-visible gradient
exchange is the psum_scatter the paper's Rule-3 schedule expects.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    # "bfloat16" halves optimizer-state HBM (beyond-paper memory trick for
    # the 314B-param single-pod cell); update math stays f32.
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params, moment_dtype: str = "float32") -> AdamWState:
    md = jnp.dtype(moment_dtype)
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, md), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(params), v=zeros(params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float, norm=None):
    """Scale ``grads`` so their global norm is at most ``max_norm``.

    ``norm`` optionally supplies a precomputed global norm (e.g.
    accumulated per-bucket); the scale formula is shared either way.
    """
    if norm is None:
        norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def apply_updates(params, grads, state: AdamWState, cfg: AdamWConfig,
                  *, grad_norm=None):
    """-> (new_params, new_state, metrics).

    ``grad_norm`` optionally supplies a precomputed global gradient norm
    (e.g. accumulated per-bucket by ``apply_updates_bucketed``); the clip
    scale is then derived from it instead of re-reducing the whole tree.
    """
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip, norm=grad_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    md = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m.astype(md),
            v.astype(md),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm,
        "lr": lr,
    }


def apply_updates_bucketed(params, bucket_grads, layout, state: AdamWState,
                           cfg: AdamWConfig):
    """``apply_updates`` from synced gradient *buckets* (no full-tree
    barrier): -> (new_params, new_state, metrics).

    ``bucket_grads`` is the list of combined 1-D buckets the overlapped
    pod sync produces (``repro.comm.bucketing`` layout).  The global-norm
    clip -- the one genuinely cross-bucket dependency -- is accumulated as
    per-bucket partial sums of squares, each computable the moment its
    bucket's sync completes; every downstream per-leaf Adam update then
    depends only on that scalar and the buckets overlapping the leaf, so
    the compiler's scheduler can start bucket k's update math while bucket
    k+1's sync is still in flight instead of waiting for a repacked tree.
    """
    from repro.comm import bucketing

    sq = sum(
        jnp.sum(jnp.square(b.astype(jnp.float32))) for b in bucket_grads
    )
    grads = bucketing.unpack_buckets(layout, bucket_grads, batch_shape=())
    return apply_updates(
        params, grads, state, cfg, grad_norm=jnp.sqrt(sq)
    )
