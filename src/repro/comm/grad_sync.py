"""Production pod-tier gradient sync, planned through ``repro.comm``.

The trainer runs the model under GSPMD on the ('data', 'model') axes and
keeps the 'pod' dim explicit (vmap over a leading [n_pods, ...] batch dim,
or shard_map ``axis_names={'pod'}`` in the reference impls): the inter-pod
DCN tier -- the paper's "global edges" -- is always scheduled by the
planner, never left to the partitioner.

Two wire formats cross the pod seam:

  'flat' -- full-precision mean of FSDP shards.  Because parameters (hence
            per-pod grads) are FSDP-sharded over 'data', each chip's shard
            is distinct and the reduce is the paper's Rule-3 parallel-egress
            exchange: 256 cross-pod pairs each move 1/256th of the gradient
            concurrently.
  'q8'   -- int8 payload + f32 block scales only (lossy, opt-in): ~4x fewer
            bytes on the DCN tier.  Decoding goes through the single
            ``q8_decode_sum`` path shared with the manual hierarchical
            all-reduce.

``select_pod_sync`` asks the cost model which format to use for a given
pod count and gradient size -- the registry guarantees whatever it picks
is runnable.
"""

from __future__ import annotations

import functools
import os
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .context import CommContext
from .impls import _axis_size, q8_decode_sum, q8_encode


# ----------------------------------------------------------------------
# shard_map reference implementations (axis_names={'pod'} regions)
# ----------------------------------------------------------------------

def _pod_mean_flat(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    return lax.psum(g, pod_axis) / n_pods


def _pod_mean_q8(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    q, scale, last = q8_encode(g)
    qg = lax.all_gather(q, pod_axis, axis=0, tiled=False)
    sg = lax.all_gather(scale, pod_axis, axis=0, tiled=False)
    return q8_decode_sum(qg, sg, last, g.shape, g.dtype, scale=1.0 / n_pods)


def pod_sync_grads(
    grads: Any, strategy: str, pod_axis: str = "pod"
) -> Any:
    """Average gradients across pods (the DCN tier), planner-chosen strategy.

    Called inside a ``shard_map(..., axis_names={pod_axis})`` region: the
    'data'/'model' axes stay GSPMD-auto, so each leaf here is the pod-local
    gradient, still sharded over the intra-pod mesh.

    strategy:
      'flat'    -- psum full-precision shards across pods.
      'q8'      -- int8-compress shards before crossing the DCN tier (lossy).
    """
    n_pods = _axis_size(pod_axis)
    if strategy == "flat":
        f = functools.partial(_pod_mean_flat, pod_axis=pod_axis, n_pods=n_pods)
    elif strategy == "q8":
        f = functools.partial(_pod_mean_q8, pod_axis=pod_axis, n_pods=n_pods)
    else:
        raise ValueError(f"unknown pod sync strategy {strategy!r}")
    return jax.tree.map(f, grads)


# ----------------------------------------------------------------------
# vmap-mode combiners (what train.steps compiles; same wire formats)
# ----------------------------------------------------------------------

POD_SYNC_FORMATS = ("flat", "q8")


def pod_combine_flat(gpod, n_pods: int):
    """Full-precision mean over the leading pod dim (see module docstring)."""
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), gpod)


def pod_combine_q8(gpod, n_pods: int, gspecs):
    """int8-compressed DCN exchange (lossy, opt-in).

    Per-pod shards quantize locally; only int8 payload + f32 block scales
    are replicated across pods (the sharding constraint pins the transfer),
    then dequantize + average locally via the shared ``q8_decode_sum``
    path.  The quantized tensors keep each leaf's own intra-pod sharding
    (gspecs = P('pod', *param_spec)); the only resharding is the pod-dim
    gather of int8 + scales.
    """

    def combine(g, gspec):
        # vmap turns q8_encode's static `last` into a traced per-pod array;
        # the true value is just g's trailing dim, so use that instead.
        q, s, _ = jax.vmap(q8_encode)(g)   # [pods, ..., nblk, 64]
        last = g.shape[-1]
        entries = list(gspec)
        while len(entries) < g.ndim:
            entries.append(None)

        def pin(x, pod_entry):
            sp = P(pod_entry, *entries[1:], None)
            try:
                return jax.lax.with_sharding_constraint(x, sp)
            except (ValueError, RuntimeError, TypeError):
                return x
        q = pin(pin(q, "pod"), None)
        s = pin(pin(s, "pod"), None)
        return q8_decode_sum(
            q, s, last, g.shape[1:], g.dtype, scale=1.0 / n_pods
        )

    return jax.tree.map(combine, gpod, gspecs,
                        is_leaf=lambda x: isinstance(x, P))


# ----------------------------------------------------------------------
# Planner-driven selection
# ----------------------------------------------------------------------

def pod_sync_topology(n_pods: int, calibration: str | None = None):
    """The topology ``pod_sync="auto"`` plans against.

    Empirically calibrated parameters win over preset constants: an explicit
    ``calibration`` path, else the file named by the ``REPRO_CALIBRATION``
    environment variable, else the ``tpu_v5e_cluster`` preset.  Calibrated
    tiers are transplanted onto the production pod shape (machine = pod).
    """
    from repro.core.topology import tpu_v5e_cluster

    preset = tpu_v5e_cluster(n_pods=n_pods)
    from .calibrate import CALIBRATION_ENV, calibrated_cluster, load_calibration

    path = calibration or os.environ.get(CALIBRATION_ENV)
    if not path:
        return preset
    calib = load_calibration(path)
    return calibrated_cluster(
        calib,
        n_machines=n_pods,
        procs_per_machine=preset.procs_per_machine,
        degree=preset.degree,
    )


def select_pod_sync(
    n_pods: int,
    grad_bytes: float,
    lossy_ok: bool = True,
    calibration: str | None = None,
) -> str:
    """Let the cost model pick the pod-sync wire format ('flat' or 'q8').

    Models the DCN tier as the machine tier of a multi-pod cluster --
    calibrated from measurements when a calibration file is supplied (or
    named by ``$REPRO_CALIBRATION``), preset v5e constants otherwise -- and
    plans a gradient all-reduce of ``grad_bytes``; returns 'q8' when the
    best executable plan is the compressed one (only reachable with
    ``lossy_ok``).
    """
    if n_pods <= 1:
        return "flat"
    ctx = CommContext(pod_sync_topology(n_pods, calibration))
    pc = ctx.plan("all_reduce", grad_bytes, lossy_ok=lossy_ok)
    return "q8" if pc.plan.lossy else "flat"
