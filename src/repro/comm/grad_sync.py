"""Production pod-tier gradient sync, planned through ``repro.comm``.

The trainer runs the model under GSPMD on the ('data', 'model') axes and
keeps the 'pod' dim explicit (vmap over a leading [n_pods, ...] batch dim,
or shard_map ``axis_names={'pod'}`` in the reference impls): the inter-pod
DCN tier -- the paper's "global edges" -- is always scheduled by the
planner, never left to the partitioner.

Four wire formats cross the pod seam:

  'flat'  -- full-precision mean of FSDP shards (parallel-egress psum).
  'q8'    -- int8 payload + f32 block scales, replicated across pods (the
             gather path: every pod receives every other pod's compressed
             gradient, ~(P-1)x the compressed bytes).  Lossy, opt-in.
  'rs'    -- reduce-scatter + all-gather: each pod sends 1/P of the
             gradient per peer and receives reduced shards back --
             bandwidth-optimal full precision.
  'rs_q8' -- the reduce-scatter exchange with int8 payload both ways:
             compressed sub-shards out, re-compressed reduced shards back.
             The cheapest DCN bytes of the four (lossy, opt-in).

On top of the wire format, the gradient can be cut into fixed-byte
**buckets** (``repro.comm.bucketing``) so bucket k's local combine overlaps
bucket k+1's global exchange -- the paper's Rule-3 tier concurrency.
``plan_pod_sync`` prices every (format, bucket count) candidate with
``simulate_pipelined`` on the (optionally calibrated) pod topology and
returns the winning ``PodSyncDecision``; ``pod_sync="auto"`` in the trainer
consumes it.  The registry guarantees whatever it picks is runnable.
"""

from __future__ import annotations

import functools
import math
import os
import warnings
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import schedules as S

from . import bucketing
from .context import CommContext
from .impls import (
    _axis_size,
    _q8_scaled_schedule,
    q8_decode,
    q8_decode_sum,
    q8_encode,
)

POD_SYNC_FORMATS = ("flat", "q8", "rs", "rs_q8")
LOSSY_POD_SYNC_FORMATS = ("q8", "rs_q8")


# ----------------------------------------------------------------------
# Sharding-constraint helper (vmap-mode combiners)
# ----------------------------------------------------------------------

_warned_pin_fallback = False


def _pin(x, sp):
    """``with_sharding_constraint`` that degrades (once, loudly) to identity.

    The vmap-mode combiners pin intermediates to 'pod'-axis specs; unit
    tests and single-host paths legitimately run them without a pod mesh in
    scope, where jax raises RuntimeError (no ambient mesh) or ValueError
    (axis not in the ambient mesh).  Only those two are swallowed -- and a
    RuntimeWarning fires on first fallback so a production run silently
    losing its DCN placement is visible, not invisible (the seed swallowed
    TypeError too, hiding genuine spec-construction bugs).
    """
    global _warned_pin_fallback
    try:
        return jax.lax.with_sharding_constraint(x, sp)
    except (ValueError, RuntimeError) as e:
        if not _warned_pin_fallback:
            _warned_pin_fallback = True
            warnings.warn(
                f"pod-sync sharding constraint {sp} not applied ({e}); "
                "gradient placement is left to the partitioner",
                RuntimeWarning,
                stacklevel=2,
            )
        return x


# ----------------------------------------------------------------------
# shard_map reference implementations (axis_names={'pod'} regions)
# ----------------------------------------------------------------------

def _pod_mean_flat(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    return lax.psum(g, pod_axis) / n_pods


def _pod_mean_q8(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    q, scale, last = q8_encode(g)
    qg = lax.all_gather(q, pod_axis, axis=0, tiled=False)
    sg = lax.all_gather(scale, pod_axis, axis=0, tiled=False)
    return q8_decode_sum(qg, sg, last, g.shape, g.dtype, scale=1.0 / n_pods)


def _pod_mean_rs(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    """Reduce-scatter + all-gather over the pod seam: 1/P per peer out,
    reduced shards back -- bandwidth-optimal, full precision."""
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n_pods
    flat = jnp.pad(flat, (0, pad))
    shard = lax.psum_scatter(flat, pod_axis, scatter_dimension=0, tiled=True)
    full = lax.all_gather(shard, pod_axis, axis=0, tiled=True)
    return (full[: g.size] / n_pods).reshape(g.shape)


def _pod_mean_rs_q8(g: jax.Array, pod_axis: str, n_pods: int) -> jax.Array:
    """The reduce-scatter exchange with int8 wire format both directions.

    Sub-shards quantize locally and cross the DCN as an all_to_all (each
    pod sends (P-1)/P of the compressed gradient); the dequantized,
    reduced shard is re-quantized for the compressed all-gather back.
    Double quantization: tolerance is ~2x the single-pass q8 error.
    """
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % n_pods
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(n_pods, -1)      # row i -> pod i's shard
    B = blocks.shape[-1]
    q, scale, last = q8_encode(blocks)
    qx = lax.all_to_all(q, pod_axis, split_axis=0, concat_axis=0, tiled=True)
    sx = lax.all_to_all(
        scale, pod_axis, split_axis=0, concat_axis=0, tiled=True
    )
    shard = q8_decode_sum(
        qx, sx, last, (B,), g.dtype, scale=1.0 / n_pods
    )
    q2, s2, last2 = q8_encode(shard)
    qg = lax.all_gather(q2, pod_axis, axis=0, tiled=False)
    sg = lax.all_gather(s2, pod_axis, axis=0, tiled=False)
    full = q8_decode(qg, sg, last2, (n_pods * B,), g.dtype)
    return full[: g.size].reshape(g.shape)


_POD_MEAN_IMPLS = {
    "flat": _pod_mean_flat,
    "q8": _pod_mean_q8,
    "rs": _pod_mean_rs,
    "rs_q8": _pod_mean_rs_q8,
}


def pod_sync_grads(
    grads: Any,
    strategy: str,
    pod_axis: str = "pod",
    bucket_bytes: int = 0,
) -> Any:
    """Average gradients across pods (the DCN tier), planner-chosen strategy.

    Called inside a ``shard_map(..., axis_names={pod_axis})`` region: the
    'data'/'model' axes stay GSPMD-auto, so each leaf here is the pod-local
    gradient, still sharded over the intra-pod mesh.

    strategy:      one of ``POD_SYNC_FORMATS`` (see module docstring).
    bucket_bytes:  when > 0, the grad tree is packed into contiguous
                   fixed-byte buckets first and each bucket synced
                   independently -- the runnable twin of the pipelined
                   schedule ``simulate_pipelined`` prices.
    """
    n_pods = _axis_size(pod_axis)
    if strategy not in _POD_MEAN_IMPLS:
        raise ValueError(
            f"unknown pod sync strategy {strategy!r}; expected one of "
            f"{POD_SYNC_FORMATS}"
        )
    f = functools.partial(
        _POD_MEAN_IMPLS[strategy], pod_axis=pod_axis, n_pods=n_pods
    )
    if bucket_bytes:
        layout = bucketing.plan_buckets(grads, bucket_bytes)
        buckets = bucketing.pack_buckets(layout, grads)
        return bucketing.unpack_buckets(layout, [f(b) for b in buckets])
    return jax.tree.map(f, grads)


# ----------------------------------------------------------------------
# vmap-mode combiners (what train.steps compiles; same wire formats)
# ----------------------------------------------------------------------

def pod_combine_flat(gpod, n_pods: int):
    """Full-precision mean over the leading pod dim (see module docstring)."""
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), gpod)


def pod_combine_q8(gpod, n_pods: int, gspecs):
    """int8-compressed DCN exchange (lossy, opt-in; the gather format).

    Per-pod shards quantize locally; only int8 payload + f32 block scales
    are replicated across pods (the sharding constraint pins the transfer),
    then dequantize + average locally via the shared ``q8_decode_sum``
    path.  The quantized tensors keep each leaf's own intra-pod sharding
    (gspecs = P('pod', *param_spec)); the only resharding is the pod-dim
    gather of int8 + scales.
    """

    def combine(g, gspec):
        # vmap turns q8_encode's static `last` into a traced per-pod array;
        # the true value is just g's trailing dim, so use that instead.
        q, s, _ = jax.vmap(q8_encode)(g)   # [pods, ..., nblk, 64]
        last = g.shape[-1]
        entries = list(gspec)
        while len(entries) < g.ndim:
            entries.append(None)

        def pin(x, pod_entry):
            return _pin(x, P(pod_entry, *entries[1:], None))
        q = pin(pin(q, "pod"), None)
        s = pin(pin(s, "pod"), None)
        return q8_decode_sum(
            q, s, last, g.shape[1:], g.dtype, scale=1.0 / n_pods
        )

    return jax.tree.map(combine, gpod, gspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _combine_1d_flat(x: jax.Array, n_pods: int) -> jax.Array:
    return jnp.mean(x, axis=0)


def _combine_1d_q8(x: jax.Array, n_pods: int) -> jax.Array:
    """Gather-format q8 on a [pods, L] bucket."""
    q, s, _ = jax.vmap(q8_encode)(x)
    last = x.shape[-1]
    q = _pin(_pin(q, P("pod", None, None)), P(None, None, None))
    s = _pin(_pin(s, P("pod", None, None)), P(None, None, None))
    return q8_decode_sum(q, s, last, x.shape[1:], x.dtype,
                         scale=1.0 / n_pods)


def _combine_1d_rs(x: jax.Array, n_pods: int) -> jax.Array:
    """RS + AG on a [pods, L] bucket, expressed through GSPMD constraints:
    the src->dest transpose is the scatter exchange, the replicating
    reshape is the all-gather."""
    L = x.shape[-1]
    pad = (-L) % n_pods
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    y = xp.reshape(n_pods, n_pods, -1)            # [src, dest, B]
    y = _pin(y, P("pod", None, None))
    z = _pin(jnp.swapaxes(y, 0, 1), P("pod", None, None))
    shard = _pin(jnp.sum(z, axis=1) / n_pods, P("pod", None))
    full = _pin(shard.reshape(-1), P(None))
    return full[:L]


def _combine_1d_rs_q8(x: jax.Array, n_pods: int) -> jax.Array:
    """Compressed RS + compressed AG on a [pods, L] bucket."""
    L = x.shape[-1]
    pad = (-L) % n_pods
    xp = jnp.pad(x, ((0, 0), (0, pad)))
    y = xp.reshape(n_pods, n_pods, -1)            # [src, dest, B]
    B = y.shape[-1]
    y = _pin(y, P("pod", None, None))
    q, s, _ = jax.vmap(jax.vmap(q8_encode))(y)    # [src, dest, nblk, 64]
    qt = _pin(jnp.swapaxes(q, 0, 1), P("pod", None, None, None))
    st = _pin(jnp.swapaxes(s, 0, 1), P("pod", None, None, None))
    acc = jnp.sum(qt.astype(jnp.float32) * st, axis=1) / n_pods
    shard = acc.reshape(n_pods, -1)[:, :B]        # [dest, B] mean shards
    shard = _pin(shard, P("pod", None))
    q2, s2, _ = jax.vmap(q8_encode)(shard)
    q2 = _pin(_pin(q2, P("pod", None, None)), P(None, None, None))
    s2 = _pin(_pin(s2, P("pod", None, None)), P(None, None, None))
    full = q8_decode(q2, s2, B, (n_pods * B,), x.dtype)
    return full[:L]


_BUCKET_COMBINERS = {
    "flat": _combine_1d_flat,
    "q8": _combine_1d_q8,
    "rs": _combine_1d_rs,
    "rs_q8": _combine_1d_rs_q8,
}


def bucket_combiner(fmt: str):
    """The ``[n_pods, L] -> [L]`` combiner of one wire format (the bucketed
    hot path; what the overlapped trainer applies per bucket)."""
    if fmt not in _BUCKET_COMBINERS:
        raise ValueError(
            f"unknown pod_sync format {fmt!r}; expected one of "
            f"{POD_SYNC_FORMATS}"
        )
    return _BUCKET_COMBINERS[fmt]


def pod_combine(gpod, n_pods: int, gspecs=None, *, fmt: str = "flat",
                bucket_bytes: int = 0):
    """vmap-mode pod-tier combine: wire format + optional bucketing.

    gpod:          grad tree, every leaf [n_pods, ...].
    gspecs:        tree of P('pod', *param_spec) leaf specs (required for
                   the unbucketed 'q8' path, which preserves per-leaf
                   intra-pod sharding; used for bucket grouping otherwise).
    fmt:           one of ``POD_SYNC_FORMATS``.
    bucket_bytes:  > 0 packs the tree into fixed-byte buckets (grouped by
                   dtype + sharding; ``repro.comm.bucketing``) and combines
                   per bucket -- the hot path the pipelined cost model
                   prices.  0 = monolithic per-leaf combine.
    """
    if fmt not in POD_SYNC_FORMATS:
        raise ValueError(
            f"unknown pod_sync format {fmt!r}; expected one of "
            f"{POD_SYNC_FORMATS}"
        )
    if bucket_bytes:
        layout = bucketing.plan_buckets(
            gpod, bucket_bytes, specs=gspecs, batch_ndim=1
        )
        buckets = bucketing.pack_buckets(layout, gpod)
        combiner = _BUCKET_COMBINERS[fmt]
        done = [combiner(b, n_pods) for b in buckets]
        return bucketing.unpack_buckets(layout, done, batch_shape=())
    if fmt == "flat":
        return pod_combine_flat(gpod, n_pods)
    if fmt == "q8":
        if gspecs is None:
            raise ValueError("pod_combine(fmt='q8') requires gspecs")
        return pod_combine_q8(gpod, n_pods, gspecs)
    combiner = _BUCKET_COMBINERS[fmt]

    def per_leaf(g):
        flat = combiner(g.reshape(n_pods, -1), n_pods)
        return flat.reshape(g.shape[1:]).astype(g.dtype)

    return jax.tree.map(per_leaf, gpod)


def pod_combine_microbatched(
    gpod_seq, n_pods: int, gspecs=None, *, fmt: str = "flat",
    bucket_bytes: int = 0, reverse: bool = True,
):
    """Per-microbatch partial-mean pod combine (the overlapped trainer's
    accumulation semantics, as a standalone reference).

    gpod_seq: grad tree, every leaf ``[accum_steps, n_pods, ...]``.  Each
    microbatch's per-pod gradients are bucketed (reverse-layer order by
    default, so buckets match backward's production order) and pod-combined
    independently; the combined partial means accumulate and the result is
    their average:

        (1/K) * sum_k pod_combine(g_k)

    For the linear wire formats ('flat'/'rs') this equals the serial
    ``pod_combine(mean_k(g_k))`` exactly per element; the q8 formats
    re-quantize per microbatch and stay within codec tolerance.  The
    trainer's overlapped step interleaves exactly this combine with the
    next microbatch's backward.
    """
    combiner = bucket_combiner(fmt)
    layout = bucketing.plan_buckets(
        gpod_seq, bucket_bytes or (1 << 62), specs=gspecs, batch_ndim=2,
        reverse=reverse,
    )
    buckets = tuple(bucketing.pack_buckets(layout, gpod_seq))
    accum = buckets[0].shape[0]

    def body(acc, bs):
        return tuple(a + combiner(b, n_pods) for a, b in zip(acc, bs)), None

    init = tuple(jnp.zeros(b.shape[2:], b.dtype) for b in buckets)
    acc, _ = lax.scan(body, init, buckets)
    return bucketing.unpack_buckets(
        layout, [a / accum for a in acc], batch_shape=()
    )


# ----------------------------------------------------------------------
# Planner-driven selection
# ----------------------------------------------------------------------

def pod_sync_topology(
    n_pods: int,
    calibration: str | None = None,
    topology: str = "v5e",
):
    """The topology ``pod_sync="auto"`` plans against.

    ``topology`` names a ``repro.core.topology.TOPOLOGY_PRESETS`` entry
    ('v5e' = the classic two-tier collapse, 'v5e_3tier' = the full
    ICI / host-PCIe / DCN hierarchy).  Empirically calibrated parameters
    win over preset constants: an explicit ``calibration`` path, else the
    file named by the ``REPRO_CALIBRATION`` environment variable, else the
    preset.  Calibrated tiers are transplanted onto the production pod
    shape (machine = pod) when the fitted hierarchy matches the preset's;
    a tier-count mismatch falls back to the preset shape of the
    calibration's own hierarchy (with a warning).
    """
    from repro.core.topology import topology_preset

    preset = topology_preset(topology, n_pods)
    from .calibrate import CALIBRATION_ENV, calibrated_cluster, load_calibration

    path = calibration or os.environ.get(CALIBRATION_ENV)
    if not path:
        return preset
    calib = load_calibration(path)
    if calib.topology.n_tiers == preset.n_tiers:
        return calibrated_cluster(
            calib, fanout=preset.fanout, degree=preset.degree
        )
    # Tier-count mismatch: keep the fitted parameters but plan on a
    # PRODUCTION-scale shape of the calibrated hierarchy (never the tiny
    # probe-mesh fanout/degree the calibration happened to run on).
    from repro.core.topology import TOPOLOGY_PRESETS

    for name in ("v5e", "v5e_3tier", *TOPOLOGY_PRESETS):
        alt = TOPOLOGY_PRESETS[name](n_pods)
        if alt.n_tiers == calib.topology.n_tiers:
            warnings.warn(
                f"calibration {path!r} fitted {calib.topology.n_tiers} "
                f"tiers but the {topology!r} preset has {preset.n_tiers}; "
                f"planning the calibrated tiers on the {name!r} preset "
                "shape",
                RuntimeWarning,
                stacklevel=2,
            )
            return calibrated_cluster(
                calib, fanout=alt.fanout, degree=alt.degree
            )
    warnings.warn(
        f"calibration {path!r} fitted {calib.topology.n_tiers} tiers but "
        f"the {topology!r} preset has {preset.n_tiers} and no preset "
        "matches; planning on the calibrated hierarchy with the preset's "
        "pod count",
        RuntimeWarning,
        stacklevel=2,
    )
    return calibrated_cluster(calib, n_machines=n_pods)


def _compose_schedules(name: str, parts) -> S.Schedule:
    """Sequential composition: one Schedule running ``parts`` back to back
    (costing only -- check_semantics does not apply to composites)."""
    out = S.Schedule(name, "pod_sync", parts[0].topo, parts[0].nbytes)
    for p in parts:
        out.rounds.extend(p.rounds)
    return out


def pod_sync_builder(topo, fmt: str):
    """``m -> Schedule``: the costable schedule family of one wire format.

    'flat'  -> the bandwidth-optimal all-reduce (what psum of FSDP shards
               lowers to at gradient sizes).
    'q8'    -> the compressed tree all-reduce (the gather-flavored format).
    'rs'    -> reduce_scatter(m) then all_gather(m/P) composed -- the
               explicit two-phase exchange the bucketed sync runs.
    'rs_q8' -> the same composition with q8-scaled global tiers.
    """
    ag_q8 = _q8_scaled_schedule(S.allgather_hier_par)
    P_ = topo.n_procs

    def build(m: float) -> S.Schedule:
        if fmt == "flat":
            return S.allreduce_hier_par_bw(topo, m, payloads=False)
        if fmt == "q8":
            return _q8_scaled_schedule(S.allreduce_hier_par)(
                topo, m, payloads=False
            )
        if fmt == "rs":
            return _compose_schedules(
                "pod_sync_rs",
                [
                    S.reducescatter_hier_par(topo, m, payloads=False),
                    S.allgather_hier_par(topo, m / P_, payloads=False),
                ],
            )
        if fmt == "rs_q8":
            return _compose_schedules(
                "pod_sync_rs_q8",
                [
                    _q8_scaled_schedule(S.reducescatter_hier_par)(
                        topo, m, payloads=False
                    ),
                    ag_q8(topo, m / P_, payloads=False),
                ],
            )
        raise ValueError(f"unknown pod_sync format {fmt!r}")

    return build


@dataclass(frozen=True)
class PodSyncDecision:
    """What the cost model chose for the pod seam: format + bucket size +
    whether the sync overlaps backward/accumulation compute."""

    fmt: str
    bucket_bytes: int          # 0 = monolithic
    n_chunks: int
    t_modelled: float          # pipelined modelled seconds for the gradient
    t_monolithic: float        # same format, single bucket
    lossy: bool
    # compute/comm overlap (0 = serial sync after the full backward;
    # > 0 = per-microbatch partial-mean sync interleaved with backward,
    # this many reverse-layer-order buckets per sync)
    overlap: int = 0
    compute_time: float = 0.0  # modelled backward+accumulation window, s
    accum_steps: int = 1
    t_step: float = 0.0        # modelled step: compute + exposed comm
    t_step_serial: float = 0.0  # best serial plan's modelled step
    dispatch_cost: float = 0.0  # per-issue overhead priced into t_step

    @property
    def bucketed(self) -> bool:
        return self.n_chunks > 1 or self.bucket_bytes > 0

    @property
    def overlapped(self) -> bool:
        return self.overlap > 0

    @property
    def speedup(self) -> float:
        return (
            self.t_monolithic / self.t_modelled if self.t_modelled else 1.0
        )

    @property
    def t_exposed(self) -> float:
        """Comm seconds the model leaves on the step's critical path."""
        return max(self.t_step - self.compute_time, 0.0)

    def describe(self) -> str:
        if not self.bucketed:
            b = "monolithic"
        elif self.n_chunks > 1:
            b = f"{self.n_chunks} x {self.bucket_bytes / 1e6:.2f}MB buckets"
        else:
            b = f"{self.bucket_bytes / 1e6:.2f}MB buckets"
        msg = (
            f"pod_sync={self.fmt} [{b}] t={self.t_modelled * 1e3:.2f}ms "
            f"(monolithic {self.t_monolithic * 1e3:.2f}ms)"
            + (" lossy" if self.lossy else "")
        )
        if self.overlapped:
            msg += (
                f" overlap={self.overlap} step={self.t_step * 1e3:.2f}ms "
                f"(serial {self.t_step_serial * 1e3:.2f}ms, "
                f"exposed {self.t_exposed * 1e3:.2f}ms)"
            )
        return msg


# Cached read of the committed BENCH_step.json fixture's dispatch fit
# (sentinel: unset / None = fixture absent or unreadable).
_FIXTURE_DISPATCH: list = []


def _fixture_dispatch_cost() -> float | None:
    """The committed ``BENCH_step.json`` fixture's fitted dispatch cost,
    seconds, or None when the fixture is absent/unreadable (installed
    packages, fresh clones before the first bench run)."""
    if not _FIXTURE_DISPATCH:
        import json
        from pathlib import Path

        fixture = Path(__file__).resolve().parents[3] / "BENCH_step.json"
        value = None
        try:
            fit_us = json.loads(fixture.read_text()).get(
                "dispatch_cost_fit_us"
            )
            if fit_us is not None:
                value = max(0.0, float(fit_us) * 1e-6)
        except (OSError, ValueError):
            value = None
        _FIXTURE_DISPATCH.append(value)
    return _FIXTURE_DISPATCH[0]


def resolve_dispatch_cost(calibration: str | None = None) -> float:
    """Per-issue dispatch overhead for overlap pricing, seconds.

    An explicit ``calibration`` file's ``meta['dispatch_cost']`` wins, else
    the file named by ``$REPRO_CALIBRATION``'s, else the committed
    ``BENCH_step.json`` fixture's ``dispatch_cost_fit_us`` (each BENCH_step
    run refreshes it via ``fit_dispatch_cost`` against the dispatch-free
    model), else ``core.simulator.DEFAULT_DISPATCH_COST``.
    """
    from repro.core.simulator import DEFAULT_DISPATCH_COST

    from .calibrate import CALIBRATION_ENV, load_calibration

    path = calibration or os.environ.get(CALIBRATION_ENV)
    if path:
        v = (load_calibration(path).meta or {}).get("dispatch_cost")
        if v is not None:
            return max(0.0, float(v))
    fixture = _fixture_dispatch_cost()
    if fixture is not None:
        return fixture
    return DEFAULT_DISPATCH_COST


def _overlap_exposure(
    stages, grad_bytes: float, n: int, compute_time: float,
    accum_steps: int, dispatch_cost: float = 0.0,
) -> float:
    """Modelled comm seconds escaping the backward shadow for the overlapped
    trainer: ``accum_steps`` partial-mean syncs of the full gradient, sync k
    hidden under microbatch k+1's backward, the last sync overlapping its
    own (final) backward through reverse-layer bucket release.

    (This is the accumulation-aware view; ``bucketing.choose_overlap``
    prices the SINGLE-sync analogue for standalone callers.  Both build on
    ``overlapped_time_affine`` -- change the exposure model there, not
    here.)

    Max of two exact bounds, each affine in the stage curves:

    * bucket-release bound: the final sync's comm that escapes its
      ``compute_time / accum_steps`` window (``overlapped_time_affine``,
      which also charges that window's ``n`` bucket dispatches);
    * work conservation: the network must move ``accum_steps`` syncs but
      only ``accum_steps - 1`` backward windows can shadow them.

    Each of the other ``accum_steps - 1`` syncs additionally stretches its
    own shadow window by ``n * dispatch_cost`` of issue overhead, which
    lands on the step's critical path on top of either bound.
    """
    w = compute_time / accum_steps
    t_pipe = bucketing.pipelined_time_affine(stages, grad_bytes, n)
    last = bucketing.overlapped_time_affine(
        stages, grad_bytes, n, w, dispatch_cost
    ) - w
    conserve = accum_steps * t_pipe - (accum_steps - 1) * w
    return max(last, conserve) + (accum_steps - 1) * n * dispatch_cost


def plan_pod_sync(
    n_pods: int,
    grad_bytes: float,
    *,
    lossy_ok: bool = True,
    calibration: str | None = None,
    topology: str = "v5e",
    bucketed: bool = True,
    bucket_bytes: int | None = None,
    topo=None,
    min_bucket_bytes: int = bucketing.MIN_BUCKET_BYTES,
    max_chunks: int = bucketing.MAX_CHUNKS,
    compute_time: float = 0.0,
    accum_steps: int = 1,
    overlap: str | int = "off",
    formats=None,
    dispatch_cost: float | None = None,
) -> PodSyncDecision:
    """Price every (wire format, bucket count, overlap depth) candidate.

    Formats are costed on the (optionally calibrated) pod topology via
    ``pod_sync_builder``; each format's bucket count is swept under the
    pipelined view (``bucketing.choose_n_chunks``), so the decision weighs
    latency amortization against tier overlap with the fitted alpha/beta --
    not folklore constants.  ``topology`` names the preset hierarchy (e.g.
    'v5e_3tier' plans the DCN seam atop the full ICI / host-PCIe / DCN
    model); ``bucket_bytes`` pins the bucket size instead of sweeping (the
    formats are then ranked AT that chunking, so a forced size cannot ride
    on another size's format choice); ``topo`` overrides the topology
    entirely (benchmarks pass the probe-mesh shape).

    ``overlap`` prices compute/comm overlap against the measured step
    compute time: 'off' keeps the serial backward -> sync -> update step;
    'auto' additionally prices the overlapped trainer (one partial-mean
    sync per microbatch riding the next microbatch's backward; see
    ``_overlap_exposure``) and picks whichever modelled STEP time wins, so
    its choice is never modelled slower than the serial plan; an int forces
    that overlap depth (buckets per sync).  Overlap needs ``accum_steps >
    1`` -- the trainer has no second backward to hide under otherwise --
    and ``compute_time`` (seconds of per-step forward+backward) to size the
    shadow.

    ``dispatch_cost`` (per-issue overhead each interleaved bucket launch
    adds to the compute path; see ``simulate_overlapped``) defaults to the
    calibration's ``meta['dispatch_cost']`` when one is in play, else the
    fixture-fitted ``DEFAULT_DISPATCH_COST``.  It penalizes only the
    overlapped candidates, so a large fitted value makes 'auto' correctly
    fall back to the serial plan.
    """
    if n_pods <= 1:
        return PodSyncDecision("flat", 0, 1, 0.0, 0.0, False)
    if dispatch_cost is None:
        dispatch_cost = resolve_dispatch_cost(calibration)
    if topo is None:
        topo = pod_sync_topology(n_pods, calibration, topology=topology)
    if formats is None:
        formats = [
            f for f in POD_SYNC_FORMATS
            if lossy_ok or f not in LOSSY_POD_SYNC_FORMATS
        ]
    forced_chunks = (
        max(1, math.ceil(grad_bytes / bucket_bytes)) if bucket_bytes else None
    )
    # int <= 0 means "no overlap", same as 'off'
    overlap_on = accum_steps > 1 and (
        overlap == "auto" or (isinstance(overlap, int) and overlap > 0)
    )
    forced_overlap = (
        overlap if isinstance(overlap, int) and overlap > 0 else None
    )
    if isinstance(overlap, int) and overlap > 0 and accum_steps <= 1:
        warnings.warn(
            f"overlap={overlap} ignored: compute/comm overlap needs "
            "accum_steps > 1 (no second backward to hide the sync under)",
            RuntimeWarning,
            stacklevel=2,
        )
    best: PodSyncDecision | None = None
    for fmt in formats:
        build = pod_sync_builder(topo, fmt)
        stages = bucketing.stage_affine(build)
        lossy = fmt in LOSSY_POD_SYNC_FORMATS
        t_mono = bucketing.pipelined_time_affine(stages, grad_bytes, 1)
        if forced_chunks is not None:
            serial_n = forced_chunks
            t_serial_sync = bucketing.pipelined_time_affine(
                stages, grad_bytes, serial_n
            )
        else:
            choice = bucketing.choose_n_chunks(
                build, grad_bytes,
                min_bucket_bytes=min_bucket_bytes,
                max_chunks=max_chunks if bucketed else 1,
                stages=stages,
            )
            serial_n, t_serial_sync = choice.n_chunks, choice.t_pipelined
        t_step_serial = compute_time + t_serial_sync
        cands = []
        if forced_overlap is None or not overlap_on:
            cands.append(
                PodSyncDecision(
                    fmt=fmt,
                    bucket_bytes=(
                        int(bucket_bytes)
                        if forced_chunks is not None
                        else int(math.ceil(grad_bytes / serial_n))
                        if serial_n > 1
                        else 0
                    ),
                    n_chunks=serial_n,
                    t_modelled=t_serial_sync,
                    t_monolithic=t_mono,
                    lossy=lossy,
                    compute_time=compute_time,
                    accum_steps=accum_steps,
                    t_step=t_step_serial,
                    t_step_serial=t_step_serial,
                )
            )
        if overlap_on:
            if forced_overlap is not None:
                ns = [max(1, forced_overlap)]
            elif forced_chunks is not None:
                ns = [forced_chunks]
            else:
                ns = bucketing.chunk_counts(
                    grad_bytes, min_bucket_bytes, max_chunks
                )
            for n in ns:
                exposed = _overlap_exposure(
                    stages, grad_bytes, n, compute_time, accum_steps,
                    dispatch_cost,
                )
                cands.append(
                    PodSyncDecision(
                        fmt=fmt,
                        bucket_bytes=int(math.ceil(grad_bytes / n)),
                        n_chunks=n,
                        t_modelled=bucketing.pipelined_time_affine(
                            stages, grad_bytes, n
                        ),
                        t_monolithic=t_mono,
                        lossy=lossy,
                        overlap=n,
                        compute_time=compute_time,
                        accum_steps=accum_steps,
                        t_step=compute_time + exposed,
                        t_step_serial=t_step_serial,
                        dispatch_cost=dispatch_cost,
                    )
                )
        for cand in cands:
            # strict <: ties prefer the earlier candidate (serial before
            # overlapped within a format, formats in POD_SYNC_FORMATS order)
            if best is None or cand.t_step < best.t_step:
                best = cand
    return best


def select_pod_sync(
    n_pods: int,
    grad_bytes: float,
    lossy_ok: bool = True,
    calibration: str | None = None,
    topology: str = "v5e",
) -> str:
    """Cost-model-chosen pod-sync wire format (one of POD_SYNC_FORMATS).

    Models the DCN tier as the machine tier of a multi-pod cluster --
    calibrated from measurements when a calibration file is supplied (or
    named by ``$REPRO_CALIBRATION``), preset constants otherwise.
    Format only; ``plan_pod_sync`` also returns the bucket size.
    """
    return plan_pod_sync(
        n_pods, grad_bytes, lossy_ok=lossy_ok, calibration=calibration,
        topology=topology, bucketed=False,
    ).fmt


# Re-exported for the planner surface; CommContext gains bucketed planning
# through this module's schedule compositions.
__all__ = [
    "POD_SYNC_FORMATS",
    "LOSSY_POD_SYNC_FORMATS",
    "PodSyncDecision",
    "bucket_combiner",
    "plan_pod_sync",
    "pod_combine",
    "pod_combine_flat",
    "pod_combine_microbatched",
    "pod_combine_q8",
    "pod_sync_builder",
    "pod_sync_grads",
    "pod_sync_topology",
    "resolve_dispatch_cost",
    "select_pod_sync",
    "CommContext",
]
