"""Empirical calibration: fit the cost model from measured collectives.

The planner's constants (``LinkTier`` alpha/beta, ``write_cost``) were
hand-specified presets until now, so plans were never confronted with
reality.  This module closes the loop, following the methodology of "Fast
Tuning of Intra-Cluster Collective Communications" (cs/0408034): time the
*executable* registry strategies across a sweep of message sizes on the real
device mesh, then least-squares-fit the model parameters so the round model
reproduces the measurements.

The workflow is probe -> fit -> plan::

    topo0 = paper_smp_cluster(n_machines=2, cores=4, nics=2)  # shape prior
    mesh = jax.make_mesh((2, 4), ("mach", "core"))
    ms = probe_collectives(topo0, mesh, sizes=[1e3, 1e4, 1e5])
    calib = fit_calibration(ms, topo0)           # CalibrationResult
    save_calibration(calib, "calibration.json")
    ctx = CommContext.from_calibration(calib)    # planner now trusts data
    ctx.crossover_table(ms)                      # did the model choose well?

Fitting exploits that ``simulate_rounds`` is *piecewise linear* in the
parameter vector (local.alpha, local.beta, global.alpha, global.beta,
write_cost, assemble_cost): each round costs its most expensive op, and for
a fixed per-round argmax the total is an exact linear function of the
parameters (``simulator.cost_features``).  We iterate weighted linear least
squares, re-linearizing at each iterate, until the argmax structure is
self-consistent -- a Gauss-Newton scheme that converges in a handful of
steps.  ``assemble_cost`` is perfectly collinear with the tier alphas (every
transfer pays exactly one of each), so it is held fixed (default 0) and the
fitted alphas absorb it.
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.simulator import cost_features, simulate_rounds
from repro.core.topology import ClusterTopology

from . import registry
from .context import plan_for_spec

# v2 serializes the topology as a tier LIST (fanout + per-tier alpha/beta),
# the N-tier generalization; v1 files (fixed local/global pair) are upgraded
# transparently by ``CalibrationResult.from_dict``.
CALIBRATION_VERSION = 2

# Environment variable naming a calibration JSON; when set, ``pod_sync="auto"``
# and other planner consumers use fitted parameters instead of presets.
CALIBRATION_ENV = "REPRO_CALIBRATION"

# Feasibility floors applied during the fit (pre-projection): solutions are
# clipped here so a noisy column can't drive a parameter negative.
_ALPHA_FLOOR = 1e-9
_BETA_FLOOR = 1e-12


def _floors(n_tiers: int) -> np.ndarray:
    """Per-parameter floors for the free vector (alphas/betas per tier,
    then write_cost)."""
    return np.array(
        [_ALPHA_FLOOR, _BETA_FLOOR] * n_tiers + [_ALPHA_FLOOR]
    )


@dataclass(frozen=True)
class Measurement:
    """One timed probe: a (collective, strategy) at message size ``nbytes``.

    nbytes:      the schedule's message size m -- bytes per proc (for
                 all_to_all: bytes per (src, dst) chunk).
    t_measured:  wall-clock seconds (min over repeats).
    t_modelled:  round-model prediction under the topology used at probe
                 time (the preset), for trajectory tracking.
    root:        the rooted collective's root proc (broadcast/gather probes
                 sweep several roots -- root placement changes which
                 machine pays egress serialization).
    shape:       (n_machines, procs_per_machine, degree) of the cluster the
                 probe ran on, or None for the calibration's full shape.
                 Single-machine probes (shape[0] == 1) are pure local-tier
                 exercises -- they pin alpha_local and write_cost, which
                 contribute only a few percent of any cluster-wide total.
    fanout:      full tier hierarchy of the probe shape (innermost first).
                 Stage probes on an N-tier topology truncate the hierarchy
                 (e.g. one pod of a 3-tier cluster probes fanout (4, 64, 1));
                 ``shape`` keeps the collapsed two-level view for
                 back-compat.  None means "derive from shape".
    """

    collective: str
    strategy: str
    nbytes: float
    t_measured: float
    t_modelled: float | None = None
    root: int = 0
    shape: tuple[int, int, int] | None = None
    fanout: tuple | None = None

    def to_dict(self) -> dict:
        return dict(
            collective=self.collective,
            strategy=self.strategy,
            nbytes=self.nbytes,
            t_measured=self.t_measured,
            t_modelled=self.t_modelled,
            root=self.root,
            shape=list(self.shape) if self.shape else None,
            fanout=list(self.fanout) if self.fanout else None,
        )

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        d = dict(d)
        if d.get("shape"):
            d["shape"] = tuple(d["shape"])
        if d.get("fanout"):
            d["fanout"] = tuple(d["fanout"])
        else:
            d.pop("fanout", None)
        return cls(**d)


@dataclass(frozen=True)
class FitResult:
    """Outcome of one least-squares fit."""

    topology: ClusterTopology
    params: tuple  # raw fitted vector, pre-projection (6 floats)
    rel_rmse: float  # root-mean-square relative residual of the fit
    n_iterations: int
    n_measurements: int


@dataclass(frozen=True)
class CalibrationResult:
    """A fitted topology plus the evidence it was fitted from."""

    topology: ClusterTopology
    measurements: tuple[Measurement, ...]
    rel_rmse: float
    n_iterations: int
    meta: dict = field(default_factory=dict, compare=False)

    def to_dict(self) -> dict:
        t = self.topology
        return dict(
            version=CALIBRATION_VERSION,
            topology=dict(
                fanout=list(t.fanout),
                degree=t.degree,
                tiers=[
                    dict(name=tier.name, alpha=tier.alpha, beta=tier.beta)
                    for tier in t.tiers
                ],
                write_cost=t.write_cost,
                assemble_cost=t.assemble_cost,
            ),
            fit=dict(rel_rmse=self.rel_rmse, n_iterations=self.n_iterations),
            meta=self.meta,
            measurements=[ms.to_dict() for ms in self.measurements],
        )

    @staticmethod
    def _upgrade_v1(d: dict) -> dict:
        """Rewrite a version-1 (fixed local/global pair) file as version 2."""
        td = d["topology"]
        out = dict(d)
        out["version"] = 2
        out["topology"] = dict(
            fanout=[td["procs_per_machine"], td["n_machines"]],
            degree=td["degree"],
            tiers=[td["local"], td["global_"]],
            write_cost=td["write_cost"],
            assemble_cost=td["assemble_cost"],
        )
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationResult":
        if d.get("version") == 1:
            d = cls._upgrade_v1(d)
        if d.get("version") != CALIBRATION_VERSION:
            raise ValueError(
                f"unsupported calibration version {d.get('version')!r} "
                f"(expected <= {CALIBRATION_VERSION})"
            )
        td = d["topology"]
        topo = ClusterTopology.fitted_tiers(
            td["fanout"],
            td["degree"],
            alphas=[tier["alpha"] for tier in td["tiers"]],
            betas=[tier["beta"] for tier in td["tiers"]],
            write_cost=td["write_cost"],
            assemble_cost=td["assemble_cost"],
            names=tuple(tier["name"] for tier in td["tiers"]),
        )
        return cls(
            topology=topo,
            measurements=tuple(
                Measurement.from_dict(m) for m in d.get("measurements", ())
            ),
            rel_rmse=d["fit"]["rel_rmse"],
            n_iterations=d["fit"]["n_iterations"],
            meta=d.get("meta", {}),
        )


def save_calibration(calib: CalibrationResult, path) -> None:
    with open(path, "w") as f:
        json.dump(calib.to_dict(), f, indent=2)


def load_calibration(path) -> CalibrationResult:
    with open(path) as f:
        return CalibrationResult.from_dict(json.load(f))


def calibrated_cluster(
    calib: CalibrationResult,
    *,
    n_machines: int | None = None,
    procs_per_machine: int | None = None,
    degree: int | None = None,
    fanout=None,
) -> ClusterTopology:
    """Fitted link tiers transplanted onto a (possibly different) shape.

    Calibration probes run on whatever mesh is available (a 2x4 fake-device
    box in CI); production plans for 2x256 pods.  Per-link alpha/beta and the
    shared-memory write cost carry over; the shape does not.  ``fanout``
    replaces the whole hierarchy shape (must have one entry per fitted
    tier); the legacy ``n_machines`` / ``procs_per_machine`` overrides
    adjust the outermost / inner extents of a two-level view.
    """
    t = calib.topology
    if fanout is not None:
        if len(fanout) != t.n_tiers:
            raise ValueError(
                f"fanout {tuple(fanout)} has {len(fanout)} levels, the "
                f"calibration fitted {t.n_tiers} tiers"
            )
        fanout = tuple(int(f) for f in fanout)
    else:
        fanout = list(t.fanout)
        if n_machines:
            fanout[-1] = n_machines
        if procs_per_machine:
            if t.n_tiers == 2:
                fanout[0] = procs_per_machine
            elif procs_per_machine != math.prod(fanout[:-1]):
                raise ValueError(
                    f"procs_per_machine={procs_per_machine} is ambiguous on "
                    f"a {t.n_tiers}-tier calibration (inner fanout "
                    f"{tuple(fanout[:-1])}); pass fanout= instead"
                )
        fanout = tuple(fanout)
    return ClusterTopology.fitted_tiers(
        fanout,
        degree or t.degree,
        alphas=[tier.alpha for tier in t.tiers],
        betas=[tier.beta for tier in t.tiers],
        write_cost=t.write_cost,
        assemble_cost=t.assemble_cost,
        names=tuple(tier.name for tier in t.tiers),
    )


# ----------------------------------------------------------------------
# Probing: time executable registry strategies on the real device mesh
# ----------------------------------------------------------------------

def _probe_m(size: float) -> float:
    """Realizable schedule message size for a target of ``size`` bytes.

    The schedule's m is bytes per proc for the symmetric collectives and
    bytes per (src, dst) chunk for all_to_all; probes carry whole float32
    elements, so the target rounds to a multiple of 4.
    """
    return max(int(size) // 4, 1) * 4.0


def _probe_array(collective: str, m: float, n_procs: int) -> np.ndarray:
    """float32 probe input of m bytes per proc (per chunk for all_to_all),
    leading dim sharded over the joint (mach, core) axes."""
    k = max(int(m) // 4, 1)
    rng = np.random.RandomState(0)
    rows = n_procs * n_procs if collective == "all_to_all" else n_procs
    return rng.randn(rows, k).astype(np.float32)


def measure_strategy(
    spec: registry.CollectiveSpec,
    mesh,
    m: float,
    *,
    mach_axis: str = "mach",
    core_axis: str = "core",
    root: int = 0,
    repeats: int = 5,
) -> float:
    """Wall-clock seconds (min over ``repeats``) for one executable strategy.

    Compiles the strategy's shard_map impl over ``mesh``, runs one warmup
    call, then times ``repeats`` synchronous calls and returns the minimum
    (the standard microbenchmark estimator: least-perturbed run).
    """
    import functools

    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if not spec.executable:
        raise registry.RegistryError(
            f"{spec.collective}/{spec.strategy} is model-only: cannot probe"
        )
    n_procs = int(np.prod(mesh.devices.shape))
    arr = _probe_array(spec.collective, m, n_procs)
    kw = dict(mach_axis=mach_axis, core_axis=core_axis)
    if spec.caps.needs_root:
        kw["root"] = root
    fn = functools.partial(spec.impl, **kw)
    f = jax.jit(
        shard_map(
            fn, mesh=mesh,
            in_specs=P((mach_axis, core_axis)),
            out_specs=P((mach_axis, core_axis)),
        )
    )
    x = jax.device_put(arr)
    jax.block_until_ready(f(x))  # compile + warmup
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_stage(
    topo: ClusterTopology,
    mesh,
    sizes,
    *,
    collectives,
    include_lossy: bool,
    repeats: int,
    mach_axis: str,
    core_axis: str,
    shape,
    verbose: bool,
) -> list[Measurement]:
    out: list[Measurement] = []
    for spec in registry.specs(executable_only=True,
                               include_lossy=include_lossy):
        if collectives is not None and spec.collective not in collectives:
            continue
        if not spec.supports(topo):
            continue
        roots = [0]
        if spec.caps.needs_root and topo.n_procs > 1:
            # Rooted calibration (ROADMAP "per-root cost caching"): sweep a
            # root in the first machine AND one in the last -- root
            # placement changes which machine pays egress serialization,
            # and on the single-machine stage the far root exercises the
            # local boundary instead.
            roots = sorted({0, topo.n_procs - 1})
        for root in roots:
            for size in sizes:
                m = _probe_m(size)
                t = measure_strategy(
                    spec, mesh, m, mach_axis=mach_axis, core_axis=core_axis,
                    root=root, repeats=repeats,
                )
                modelled = plan_for_spec(topo, spec, m, root=root).t_rounds
                out.append(
                    Measurement(
                        collective=spec.collective,
                        strategy=spec.strategy,
                        nbytes=m,
                        t_measured=t,
                        t_modelled=modelled,
                        root=root,
                        shape=shape,
                        fanout=topo.fanout,
                    )
                )
                if verbose:
                    print(
                        f"[probe] {'x'.join(map(str, topo.fanout))} "
                        f"{spec.collective}/{spec.strategy} m={m:.0f}B "
                        f"root={root} measured={t * 1e6:.1f}us "
                        f"modelled={modelled * 1e6:.1f}us"
                    )
    return out


def probe_collectives(
    topo: ClusterTopology,
    mesh,
    sizes,
    *,
    collectives=None,
    include_lossy: bool = True,
    local_stage: bool = True,
    repeats: int = 5,
    mach_axis: str = "mach",
    core_axis: str = "core",
    verbose: bool = False,
) -> list[Measurement]:
    """Time every executable registry strategy across a message-size sweep.

    ``topo`` supplies the model's shape (and the preset prediction recorded
    in ``t_modelled``); it must mirror ``mesh``'s (mach, core) extents.
    ``sizes`` are target bytes per proc.

    When ``local_stage`` is set, one extra sweep runs per *inner* tier
    boundary on a truncated sub-mesh (stage ``l`` keeps one level-``l``
    group: the classic single-machine stage for a two-tier topology, plus
    e.g. a one-pod stage and a one-host stage for a three-tier one).  Those
    probes exercise only the inner tiers and the shared-memory write, which
    cluster-wide totals barely expose -- without them the fit cannot
    separate each boundary's alpha/beta from noise (the tuning papers'
    per-tier probe methodology, stage-per-tier).
    """
    mm, cc = (dict(zip(mesh.axis_names, mesh.devices.shape))[a]
              for a in (mach_axis, core_axis))
    if (topo.n_machines, topo.procs_per_machine) != (mm, cc):
        raise ValueError(
            f"topology shape {topo.n_machines}x{topo.procs_per_machine} does "
            f"not mirror mesh shape {mm}x{cc}"
        )
    kw = dict(
        collectives=collectives, include_lossy=include_lossy,
        repeats=repeats, mach_axis=mach_axis, core_axis=core_axis,
        verbose=verbose,
    )
    out = _probe_stage(
        topo, mesh, sizes,
        shape=(topo.n_machines, topo.procs_per_machine, topo.degree), **kw,
    )
    if local_stage:
        from jax.sharding import Mesh

        ax = list(mesh.axis_names)
        for level in range(topo.n_tiers - 1, 0, -1):
            stage_topo = topo.stage(level)
            if stage_topo.n_procs == topo.n_procs:
                continue  # outermost extent already 1: the full sweep is it
            idx = [slice(None)] * mesh.devices.ndim
            idx[ax.index(mach_axis)] = slice(0, 1)
            idx[ax.index(core_axis)] = slice(0, stage_topo.procs_per_machine)
            sub_mesh = Mesh(mesh.devices[tuple(idx)], mesh.axis_names)
            out += _probe_stage(
                stage_topo, sub_mesh, sizes,
                shape=(1, stage_topo.procs_per_machine, topo.degree), **kw,
            )
    return out


# ----------------------------------------------------------------------
# Fitting: iterated weighted linear least squares on cost_features
# ----------------------------------------------------------------------

def fit_topology(
    measurements,
    n_machines: int | None = None,
    procs_per_machine: int | None = None,
    degree: int = 1,
    *,
    fanout=None,
    assemble_cost: float = 0.0,
    include_lossy: bool = False,
    max_iter: int = 12,
    tol: float = 1e-4,
) -> FitResult:
    """Least-squares-fit per-tier alpha/beta and write_cost from timings.

    The fitted hierarchy is ``fanout`` (innermost first, one entry per link
    tier); the legacy positional (n_machines, procs_per_machine) pair is
    the two-tier shorthand ``fanout=(procs_per_machine, n_machines)``.
    Minimizes the *relative* residual sum((model(theta) - t) / t)^2 over
    theta = (alpha_0, beta_0, ..., alpha_{T-1}, beta_{T-1}, write_cost);
    relative weighting keeps microsecond-scale small-message rows (which
    pin the alphas) from being drowned by millisecond-scale large-message
    rows (which pin the betas).  ``assemble_cost`` is held fixed (it is
    exactly collinear with the alphas -- see module docstring).

    Measurements from truncated probe stages (``Measurement.fanout``
    shorter than the fit's) contribute columns only for the tiers they
    exercise -- the stage-per-tier methodology that lets the fit separate
    each boundary's alpha/beta from noise.

    Lossy (q8) probes are excluded by default: their wall-clock includes
    encode/decode compute the wire model doesn't describe.
    """
    if fanout is None:
        if n_machines is None or procs_per_machine is None:
            raise ValueError(
                "pass fanout= (N-tier) or the legacy "
                "(n_machines, procs_per_machine) pair"
            )
        fanout = (procs_per_machine, n_machines)
    fanout = tuple(int(f) for f in fanout)
    T = len(fanout)
    width = 2 * T + 2  # per-tier (alpha, beta) + (write, assemble)
    n_free = 2 * T + 1
    ms = [
        m for m in measurements
        if include_lossy or not registry.get_spec(m.collective, m.strategy).lossy
    ]
    if len(ms) < n_free:
        raise ValueError(
            f"need >= {n_free} measurements to fit {n_free} parameters, "
            f"got {len(ms)}"
        )
    # Schedule structure (ops, bytes, rounds) depends only on the cluster
    # shape, never on the tier parameters -- build once per measurement
    # (honoring its probe shape), then re-linearize cheaply each iteration.
    shape_topo = ClusterTopology.fitted_tiers(
        fanout, degree,
        alphas=[1e-6] * T, betas=[1e-9] * T,
        write_cost=1e-6, assemble_cost=assemble_cost,
    )

    def shape_of(m: Measurement) -> tuple:
        """(fanout, degree) of the probe, defaulting to the fit's own."""
        if m.fanout is not None:
            fan = tuple(m.fanout)
        elif m.shape is not None:
            fan = (m.shape[1], m.shape[0])
        else:
            return fanout, degree
        deg = m.shape[2] if m.shape is not None else degree
        return fan, deg

    def build_all(base: ClusterTopology | None = None):
        src = base if base is not None else shape_topo
        out = []
        for m in ms:
            fan, deg = shape_of(m)
            topo_m = src if (fan, deg) == (fanout, degree) \
                else src.with_shape(fan, deg)
            out.append(
                registry.get_spec(m.collective, m.strategy).build_schedule(
                    topo_m, m.nbytes, root=m.root, payloads=False
                )
            )
        return out

    def feature_matrix(scheds, theta) -> np.ndarray:
        """Full-width rows; truncated-stage schedules only populate the
        columns of the tiers they exercise (tier identity is preserved by
        truncation: stage tiers ARE the innermost fit tiers)."""
        F = np.zeros((len(scheds), width))
        for i, s in enumerate(scheds):
            Ts = s.topo.n_tiers
            sub = tuple(theta[: 2 * Ts]) + (theta[-2], theta[-1])
            row = cost_features(s, params=sub)
            F[i, : 2 * Ts] = row[: 2 * Ts]
            F[i, -2:] = row[-2:]
        return F

    scheds = build_all()
    t = np.array([m.t_measured for m in ms])
    wts = 1.0 / np.maximum(t, 1e-12)
    theta = np.array(shape_topo.param_vector())
    floors = _floors(T)
    n_iter = 0
    for n_iter in range(1, max_iter + 1):
        F = feature_matrix(scheds, theta)
        rhs = (t - F[:, -1] * assemble_cost) * wts
        sol, *_ = np.linalg.lstsq(
            F[:, :n_free] * wts[:, None], rhs, rcond=None
        )
        sol = np.maximum(sol, floors)
        # Project onto the model's feasible region (Rule 2: every tier at
        # least as fast as the tier outside it) EVERY iteration, not just
        # at the end: the argmax re-linearization is only self-correcting
        # from a feasible iterate -- an infeasible one (an inner tier
        # "slower" than an outer one) labels the wrong op as each round's
        # bottleneck and the iteration can converge to a spurious fixed
        # point.
        for i in range(T - 2, -1, -1):
            sol[2 * i] = min(sol[2 * i], sol[2 * (i + 1)])
            sol[2 * i + 1] = min(sol[2 * i + 1], sol[2 * (i + 1) + 1])
        new = np.concatenate([sol, [assemble_cost]])
        delta = float(np.max(np.abs(new - theta) / np.maximum(theta, 1e-12)))
        theta = new
        if delta < tol:
            break
    topo = ClusterTopology.fitted_tiers(
        fanout, degree,
        alphas=[theta[2 * i] for i in range(T)],
        betas=[theta[2 * i + 1] for i in range(T)],
        write_cost=theta[-2], assemble_cost=assemble_cost,
    )
    # Report the residual of the *projected* topology (what callers plan
    # with), not the raw iterate.
    pred = np.array([
        simulate_rounds(s, check=False) for s in build_all(base=topo)
    ])
    rel_rmse = float(np.sqrt(np.mean(((pred - t) / t) ** 2)))
    return FitResult(
        topology=topo,
        params=tuple(float(x) for x in theta),
        rel_rmse=rel_rmse,
        n_iterations=n_iter,
        n_measurements=len(ms),
    )


def fit_calibration(
    measurements,
    shape_like: ClusterTopology,
    *,
    assemble_cost: float = 0.0,
    include_lossy: bool = False,
    meta: dict | None = None,
) -> CalibrationResult:
    """``fit_topology`` + provenance packaging for persistence."""
    fit = fit_topology(
        measurements,
        degree=shape_like.degree,
        fanout=shape_like.fanout,
        assemble_cost=assemble_cost,
        include_lossy=include_lossy,
    )
    return CalibrationResult(
        topology=fit.topology,
        measurements=tuple(measurements),
        rel_rmse=fit.rel_rmse,
        n_iterations=fit.n_iterations,
        meta=dict(meta or {}, n_fit_measurements=fit.n_measurements),
    )


def calibrate(
    topo: ClusterTopology,
    mesh,
    sizes=(1024.0, 16384.0, 262144.0),
    *,
    repeats: int = 5,
    collectives=None,
    mach_axis: str = "mach",
    core_axis: str = "core",
    verbose: bool = False,
    meta: dict | None = None,
) -> CalibrationResult:
    """One-call probe -> fit on the current device mesh.

    ``topo`` is the shape prior (its tier constants are only used for the
    ``t_modelled`` trajectory column); the returned calibration carries a
    topology of the same shape with *fitted* parameters.
    """
    ms = probe_collectives(
        topo, mesh, sizes, collectives=collectives, repeats=repeats,
        mach_axis=mach_axis, core_axis=core_axis, verbose=verbose,
    )
    base_meta = dict(
        mesh_shape=list(mesh.devices.shape),
        sizes=[float(s) for s in sizes],
        repeats=repeats,
    )
    return fit_calibration(ms, topo, meta=dict(base_meta, **(meta or {})))
