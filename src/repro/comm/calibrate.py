"""Empirical calibration: fit the cost model from measured collectives.

The planner's constants (``LinkTier`` alpha/beta, ``write_cost``) were
hand-specified presets until now, so plans were never confronted with
reality.  This module closes the loop, following the methodology of "Fast
Tuning of Intra-Cluster Collective Communications" (cs/0408034): time the
*executable* registry strategies across a sweep of message sizes on the real
device mesh, then least-squares-fit the model parameters so the round model
reproduces the measurements.

The workflow is probe -> fit -> plan::

    topo0 = paper_smp_cluster(n_machines=2, cores=4, nics=2)  # shape prior
    mesh = jax.make_mesh((2, 4), ("mach", "core"))
    ms = probe_collectives(topo0, mesh, sizes=[1e3, 1e4, 1e5])
    calib = fit_calibration(ms, topo0)           # CalibrationResult
    save_calibration(calib, "calibration.json")
    ctx = CommContext.from_calibration(calib)    # planner now trusts data
    ctx.crossover_table(ms)                      # did the model choose well?

Fitting exploits that ``simulate_rounds`` is *piecewise linear* in the
parameter vector (local.alpha, local.beta, global.alpha, global.beta,
write_cost, assemble_cost): each round costs its most expensive op, and for
a fixed per-round argmax the total is an exact linear function of the
parameters (``simulator.cost_features``).  We iterate weighted linear least
squares, re-linearizing at each iterate, until the argmax structure is
self-consistent -- a Gauss-Newton scheme that converges in a handful of
steps.  ``assemble_cost`` is perfectly collinear with the tier alphas (every
transfer pays exactly one of each), so it is held fixed (default 0) and the
fitted alphas absorb it.
"""

from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.simulator import cost_features, simulate_rounds
from repro.core.topology import ClusterTopology

from . import registry
from .context import plan_for_spec

CALIBRATION_VERSION = 1

# Environment variable naming a calibration JSON; when set, ``pod_sync="auto"``
# and other planner consumers use fitted parameters instead of presets.
CALIBRATION_ENV = "REPRO_CALIBRATION"

# Feasibility floors applied during the fit (pre-projection): solutions are
# clipped here so a noisy column can't drive a parameter negative.
_FLOORS = np.array([1e-9, 1e-12, 1e-9, 1e-12, 1e-9])


@dataclass(frozen=True)
class Measurement:
    """One timed probe: a (collective, strategy) at message size ``nbytes``.

    nbytes:      the schedule's message size m -- bytes per proc (for
                 all_to_all: bytes per (src, dst) chunk).
    t_measured:  wall-clock seconds (min over repeats).
    t_modelled:  round-model prediction under the topology used at probe
                 time (the preset), for trajectory tracking.
    shape:       (n_machines, procs_per_machine, degree) of the cluster the
                 probe ran on, or None for the calibration's full shape.
                 Single-machine probes (shape[0] == 1) are pure local-tier
                 exercises -- they pin alpha_local and write_cost, which
                 contribute only a few percent of any cluster-wide total.
    """

    collective: str
    strategy: str
    nbytes: float
    t_measured: float
    t_modelled: float | None = None
    root: int = 0
    shape: tuple[int, int, int] | None = None

    def to_dict(self) -> dict:
        return dict(
            collective=self.collective,
            strategy=self.strategy,
            nbytes=self.nbytes,
            t_measured=self.t_measured,
            t_modelled=self.t_modelled,
            root=self.root,
            shape=list(self.shape) if self.shape else None,
        )

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        d = dict(d)
        if d.get("shape"):
            d["shape"] = tuple(d["shape"])
        return cls(**d)


@dataclass(frozen=True)
class FitResult:
    """Outcome of one least-squares fit."""

    topology: ClusterTopology
    params: tuple  # raw fitted vector, pre-projection (6 floats)
    rel_rmse: float  # root-mean-square relative residual of the fit
    n_iterations: int
    n_measurements: int


@dataclass(frozen=True)
class CalibrationResult:
    """A fitted topology plus the evidence it was fitted from."""

    topology: ClusterTopology
    measurements: tuple[Measurement, ...]
    rel_rmse: float
    n_iterations: int
    meta: dict = field(default_factory=dict, compare=False)

    def to_dict(self) -> dict:
        t = self.topology
        return dict(
            version=CALIBRATION_VERSION,
            topology=dict(
                n_machines=t.n_machines,
                procs_per_machine=t.procs_per_machine,
                degree=t.degree,
                local=dict(name=t.local.name, alpha=t.local.alpha,
                           beta=t.local.beta),
                global_=dict(name=t.global_.name, alpha=t.global_.alpha,
                             beta=t.global_.beta),
                write_cost=t.write_cost,
                assemble_cost=t.assemble_cost,
            ),
            fit=dict(rel_rmse=self.rel_rmse, n_iterations=self.n_iterations),
            meta=self.meta,
            measurements=[ms.to_dict() for ms in self.measurements],
        )

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationResult":
        if d.get("version") != CALIBRATION_VERSION:
            raise ValueError(
                f"unsupported calibration version {d.get('version')!r} "
                f"(expected {CALIBRATION_VERSION})"
            )
        td = d["topology"]
        topo = ClusterTopology.fitted(
            td["n_machines"], td["procs_per_machine"], td["degree"],
            alpha_local=td["local"]["alpha"],
            beta_local=td["local"]["beta"],
            alpha_global=td["global_"]["alpha"],
            beta_global=td["global_"]["beta"],
            write_cost=td["write_cost"],
            assemble_cost=td["assemble_cost"],
            local_name=td["local"]["name"],
            global_name=td["global_"]["name"],
        )
        return cls(
            topology=topo,
            measurements=tuple(
                Measurement.from_dict(m) for m in d.get("measurements", ())
            ),
            rel_rmse=d["fit"]["rel_rmse"],
            n_iterations=d["fit"]["n_iterations"],
            meta=d.get("meta", {}),
        )


def save_calibration(calib: CalibrationResult, path) -> None:
    with open(path, "w") as f:
        json.dump(calib.to_dict(), f, indent=2)


def load_calibration(path) -> CalibrationResult:
    with open(path) as f:
        return CalibrationResult.from_dict(json.load(f))


def calibrated_cluster(
    calib: CalibrationResult,
    *,
    n_machines: int | None = None,
    procs_per_machine: int | None = None,
    degree: int | None = None,
) -> ClusterTopology:
    """Fitted link tiers transplanted onto a (possibly different) shape.

    Calibration probes run on whatever mesh is available (a 2x4 fake-device
    box in CI); production plans for 2x256 pods.  Per-link alpha/beta and the
    shared-memory write cost carry over; the shape does not.
    """
    t = calib.topology
    return ClusterTopology.fitted(
        n_machines or t.n_machines,
        procs_per_machine or t.procs_per_machine,
        degree or t.degree,
        alpha_local=t.local.alpha,
        beta_local=t.local.beta,
        alpha_global=t.global_.alpha,
        beta_global=t.global_.beta,
        write_cost=t.write_cost,
        assemble_cost=t.assemble_cost,
        local_name=t.local.name,
        global_name=t.global_.name,
    )


# ----------------------------------------------------------------------
# Probing: time executable registry strategies on the real device mesh
# ----------------------------------------------------------------------

def _probe_m(size: float) -> float:
    """Realizable schedule message size for a target of ``size`` bytes.

    The schedule's m is bytes per proc for the symmetric collectives and
    bytes per (src, dst) chunk for all_to_all; probes carry whole float32
    elements, so the target rounds to a multiple of 4.
    """
    return max(int(size) // 4, 1) * 4.0


def _probe_array(collective: str, m: float, n_procs: int) -> np.ndarray:
    """float32 probe input of m bytes per proc (per chunk for all_to_all),
    leading dim sharded over the joint (mach, core) axes."""
    k = max(int(m) // 4, 1)
    rng = np.random.RandomState(0)
    rows = n_procs * n_procs if collective == "all_to_all" else n_procs
    return rng.randn(rows, k).astype(np.float32)


def measure_strategy(
    spec: registry.CollectiveSpec,
    mesh,
    m: float,
    *,
    mach_axis: str = "mach",
    core_axis: str = "core",
    root: int = 0,
    repeats: int = 5,
) -> float:
    """Wall-clock seconds (min over ``repeats``) for one executable strategy.

    Compiles the strategy's shard_map impl over ``mesh``, runs one warmup
    call, then times ``repeats`` synchronous calls and returns the minimum
    (the standard microbenchmark estimator: least-perturbed run).
    """
    import functools

    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if not spec.executable:
        raise registry.RegistryError(
            f"{spec.collective}/{spec.strategy} is model-only: cannot probe"
        )
    n_procs = int(np.prod(mesh.devices.shape))
    arr = _probe_array(spec.collective, m, n_procs)
    kw = dict(mach_axis=mach_axis, core_axis=core_axis)
    if spec.caps.needs_root:
        kw["root"] = root
    fn = functools.partial(spec.impl, **kw)
    f = jax.jit(
        shard_map(
            fn, mesh=mesh,
            in_specs=P((mach_axis, core_axis)),
            out_specs=P((mach_axis, core_axis)),
        )
    )
    x = jax.device_put(arr)
    jax.block_until_ready(f(x))  # compile + warmup
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return best


def _probe_stage(
    topo: ClusterTopology,
    mesh,
    sizes,
    *,
    collectives,
    include_lossy: bool,
    repeats: int,
    mach_axis: str,
    core_axis: str,
    shape,
    verbose: bool,
) -> list[Measurement]:
    out: list[Measurement] = []
    for spec in registry.specs(executable_only=True,
                               include_lossy=include_lossy):
        if collectives is not None and spec.collective not in collectives:
            continue
        if not spec.supports(topo):
            continue
        for size in sizes:
            m = _probe_m(size)
            t = measure_strategy(
                spec, mesh, m, mach_axis=mach_axis, core_axis=core_axis,
                repeats=repeats,
            )
            modelled = plan_for_spec(topo, spec, m).t_rounds
            out.append(
                Measurement(
                    collective=spec.collective,
                    strategy=spec.strategy,
                    nbytes=m,
                    t_measured=t,
                    t_modelled=modelled,
                    shape=shape,
                )
            )
            if verbose:
                print(
                    f"[probe] {topo.n_machines}x{topo.procs_per_machine} "
                    f"{spec.collective}/{spec.strategy} m={m:.0f}B "
                    f"measured={t * 1e6:.1f}us modelled={modelled * 1e6:.1f}us"
                )
    return out


def probe_collectives(
    topo: ClusterTopology,
    mesh,
    sizes,
    *,
    collectives=None,
    include_lossy: bool = True,
    local_stage: bool = True,
    repeats: int = 5,
    mach_axis: str = "mach",
    core_axis: str = "core",
    verbose: bool = False,
) -> list[Measurement]:
    """Time every executable registry strategy across a message-size sweep.

    ``topo`` supplies the model's shape (and the preset prediction recorded
    in ``t_modelled``); it must mirror ``mesh``'s (mach, core) extents.
    ``sizes`` are target bytes per proc.

    When ``local_stage`` is set (and the mesh spans more than one machine),
    a second sweep runs on a single-machine sub-mesh (the first machine's
    cores).  Those probes exercise only the local tier and the shared-memory
    write, which cluster-wide totals barely expose -- without them the fit
    cannot separate alpha_local/write_cost from noise (the tuning papers'
    per-tier probe methodology).
    """
    mm, cc = (dict(zip(mesh.axis_names, mesh.devices.shape))[a]
              for a in (mach_axis, core_axis))
    if (topo.n_machines, topo.procs_per_machine) != (mm, cc):
        raise ValueError(
            f"topology shape {topo.n_machines}x{topo.procs_per_machine} does "
            f"not mirror mesh shape {mm}x{cc}"
        )
    kw = dict(
        collectives=collectives, include_lossy=include_lossy,
        repeats=repeats, mach_axis=mach_axis, core_axis=core_axis,
        verbose=verbose,
    )
    out = _probe_stage(
        topo, mesh, sizes,
        shape=(topo.n_machines, topo.procs_per_machine, topo.degree), **kw,
    )
    if local_stage and topo.n_machines > 1:
        from jax.sharding import Mesh

        ax = list(mesh.axis_names)
        idx = [slice(None)] * mesh.devices.ndim
        idx[ax.index(mach_axis)] = slice(0, 1)
        sub_mesh = Mesh(mesh.devices[tuple(idx)], mesh.axis_names)
        sub_topo = topo.with_(n_machines=1)
        out += _probe_stage(
            sub_topo, sub_mesh, sizes,
            shape=(1, topo.procs_per_machine, topo.degree), **kw,
        )
    return out


# ----------------------------------------------------------------------
# Fitting: iterated weighted linear least squares on cost_features
# ----------------------------------------------------------------------

def fit_topology(
    measurements,
    n_machines: int,
    procs_per_machine: int,
    degree: int,
    *,
    assemble_cost: float = 0.0,
    include_lossy: bool = False,
    max_iter: int = 12,
    tol: float = 1e-4,
) -> FitResult:
    """Least-squares-fit per-tier alpha/beta and write_cost from timings.

    Minimizes the *relative* residual sum((model(theta) - t) / t)^2 over
    theta = (alpha_l, beta_l, alpha_g, beta_g, write_cost); relative
    weighting keeps microsecond-scale small-message rows (which pin the
    alphas) from being drowned by millisecond-scale large-message rows
    (which pin the betas).  ``assemble_cost`` is held fixed (it is exactly
    collinear with the alphas -- see module docstring).

    Lossy (q8) probes are excluded by default: their wall-clock includes
    encode/decode compute the wire model doesn't describe.
    """
    ms = [
        m for m in measurements
        if include_lossy or not registry.get_spec(m.collective, m.strategy).lossy
    ]
    if len(ms) < 5:
        raise ValueError(
            f"need >= 5 measurements to fit 5 parameters, got {len(ms)}"
        )
    # Schedule structure (ops, bytes, rounds) depends only on the cluster
    # shape, never on the tier parameters -- build once per measurement
    # (honoring its probe shape), then re-linearize cheaply each iteration.
    shape_topo = ClusterTopology.fitted(
        n_machines, procs_per_machine, degree,
        alpha_local=1e-6, beta_local=1e-9, alpha_global=1e-6, beta_global=1e-9,
        write_cost=1e-6, assemble_cost=assemble_cost,
    )

    def topo_of(m: Measurement) -> ClusterTopology:
        if m.shape is None or m.shape == (n_machines, procs_per_machine, degree):
            return shape_topo
        return shape_topo.with_(
            n_machines=m.shape[0], procs_per_machine=m.shape[1],
            degree=m.shape[2],
        )

    def build_all(base: ClusterTopology | None = None):
        out = []
        for m in ms:
            topo_m = topo_of(m)
            if base is not None:
                topo_m = base.with_(
                    n_machines=topo_m.n_machines,
                    procs_per_machine=topo_m.procs_per_machine,
                    degree=topo_m.degree,
                )
            out.append(
                registry.get_spec(m.collective, m.strategy).build_schedule(
                    topo_m, m.nbytes, root=m.root, payloads=False
                )
            )
        return out

    scheds = build_all()
    t = np.array([m.t_measured for m in ms])
    wts = 1.0 / np.maximum(t, 1e-12)
    theta = np.array(shape_topo.param_vector())
    n_iter = 0
    for n_iter in range(1, max_iter + 1):
        F = np.array([cost_features(s, params=tuple(theta)) for s in scheds])
        rhs = (t - F[:, 5] * assemble_cost) * wts
        sol, *_ = np.linalg.lstsq(F[:, :5] * wts[:, None], rhs, rcond=None)
        sol = np.maximum(sol, _FLOORS)
        # Project onto the model's feasible region (Rule 2: local at least
        # as fast as global) EVERY iteration, not just at the end: the
        # argmax re-linearization is only self-correcting from a feasible
        # iterate -- an infeasible one (local "slower" than global) labels
        # the wrong op as each round's bottleneck and the iteration can
        # converge to a spurious fixed point.
        sol[0] = min(sol[0], sol[2])
        sol[1] = min(sol[1], sol[3])
        new = np.concatenate([sol, [assemble_cost]])
        delta = float(np.max(np.abs(new - theta) / np.maximum(theta, 1e-12)))
        theta = new
        if delta < tol:
            break
    topo = ClusterTopology.fitted(
        n_machines, procs_per_machine, degree,
        alpha_local=theta[0], beta_local=theta[1],
        alpha_global=theta[2], beta_global=theta[3],
        write_cost=theta[4], assemble_cost=assemble_cost,
    )
    # Report the residual of the *projected* topology (what callers plan
    # with), not the raw iterate.
    pred = np.array([
        simulate_rounds(s, check=False) for s in build_all(base=topo)
    ])
    rel_rmse = float(np.sqrt(np.mean(((pred - t) / t) ** 2)))
    return FitResult(
        topology=topo,
        params=tuple(float(x) for x in theta),
        rel_rmse=rel_rmse,
        n_iterations=n_iter,
        n_measurements=len(ms),
    )


def fit_calibration(
    measurements,
    shape_like: ClusterTopology,
    *,
    assemble_cost: float = 0.0,
    include_lossy: bool = False,
    meta: dict | None = None,
) -> CalibrationResult:
    """``fit_topology`` + provenance packaging for persistence."""
    fit = fit_topology(
        measurements,
        shape_like.n_machines,
        shape_like.procs_per_machine,
        shape_like.degree,
        assemble_cost=assemble_cost,
        include_lossy=include_lossy,
    )
    return CalibrationResult(
        topology=fit.topology,
        measurements=tuple(measurements),
        rel_rmse=fit.rel_rmse,
        n_iterations=fit.n_iterations,
        meta=dict(meta or {}, n_fit_measurements=fit.n_measurements),
    )


def calibrate(
    topo: ClusterTopology,
    mesh,
    sizes=(1024.0, 16384.0, 262144.0),
    *,
    repeats: int = 5,
    collectives=None,
    mach_axis: str = "mach",
    core_axis: str = "core",
    verbose: bool = False,
    meta: dict | None = None,
) -> CalibrationResult:
    """One-call probe -> fit on the current device mesh.

    ``topo`` is the shape prior (its tier constants are only used for the
    ``t_modelled`` trajectory column); the returned calibration carries a
    topology of the same shape with *fitted* parameters.
    """
    ms = probe_collectives(
        topo, mesh, sizes, collectives=collectives, repeats=repeats,
        mach_axis=mach_axis, core_axis=core_axis, verbose=verbose,
    )
    base_meta = dict(
        mesh_shape=list(mesh.devices.shape),
        sizes=[float(s) for s in sizes],
        repeats=repeats,
    )
    return fit_calibration(ms, topo, meta=dict(base_meta, **(meta or {})))
