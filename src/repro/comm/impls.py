"""Runnable realizations of the registered collective strategies.

Every function here executes inside a ``shard_map`` region over a
("mach", "core") mesh -- the paper's two-tier cluster mapped onto devices.
Each is registered against its schedule generator via ``@register_strategy``,
so the cost model and the runtime can never drift: the planner costs exactly
the schedule whose runnable twin is bound in the same ``CollectiveSpec``.

Strategy naming follows the schedule generators:

  * ``flat``          -- hierarchy-oblivious (the paper's strawman),
  * ``hier_seq``      -- single-leader hierarchical (model-only strawman),
  * ``hier_par``      -- the paper's Rule-1/2/3-aware schedule,
  * ``hier_par_bw``   -- bandwidth-optimal large-message variant,
  * ``*_q8``          -- int8-compressed global tier (lossy, opt-in).

The int8 codec quantizes blocks of 64 values to int8 with an f32 scale
before crossing the DCN tier: 4.25 bytes -> 1.0625 bytes per f32 value,
a ~4x cut of the global-tier collective term.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import schedules as S

from .registry import Capabilities, register_model_only, register_strategy

Q8_BLOCK = 64


def _axis_size(name) -> int:
    """Static mesh-axis size inside a shard_map region.

    ``lax.axis_size`` only exists on newer jax; ``lax.psum`` of a Python
    scalar constant-folds to the axis size (a plain int) on the pinned
    version, so reshapes downstream stay static either way.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(name)
    return lax.psum(1, name)

# Quantized-DCN schedule variant: global-tier bytes shrink by this factor
# (fp32 -> int8 values + per-block fp32 scales).  Lossy, so the planner
# reports it separately and selects it only when the caller opts in.
Q8_GLOBAL_FACTOR = 0.2656  # 1/4 payload + 1/64-block fp32 scales


# ----------------------------------------------------------------------
# int8 block codec (for the DCN tier)
# ----------------------------------------------------------------------

def q8_encode(x: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """Blockwise symmetric int8 quantization over the last axis.

    Blocks the LAST dim only (padded to a multiple of Q8_BLOCK) and keeps
    the leading dims -- no giant flatten, so >2^31-element tensors (the
    stacked 40x8192x22528 mlp grads) stay within int32 index arithmetic.
    Returns (q [..., nblk, B], scales [..., nblk, 1], last_dim)."""
    last = x.shape[-1]
    pad = (-last) % Q8_BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], -1, Q8_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), last


def q8_decode(q: jax.Array, scale: jax.Array, last: int, shape, dtype) -> jax.Array:
    out = (q.astype(jnp.float32) * scale)
    out = out.reshape(*out.shape[:-2], -1)[..., :last]
    return out.reshape(shape).astype(dtype)


def q8_decode_sum(
    qg: jax.Array,
    sg: jax.Array,
    last: int,
    shape,
    dtype,
    scale: float = 1.0,
) -> jax.Array:
    """THE decode path for every gathered-q8 reduction in this repo.

    Input is a leading-axis stack of per-peer (q, scale) blocks from an
    ``all_gather`` over the compressed tier.  Dequantize-and-accumulate in
    one fused expression (sum_i q_i * s_i, optionally scaled by e.g.
    1/n_pods for a mean), then unblock back to ``shape``.  Both the manual
    hierarchical all-reduce and the production pod-tier gradient sync call
    this -- previously each carried its own copy with a dead ``deq / 1.0``
    / ``jnp.ones_like`` re-decode bolted on.
    """
    acc = jnp.sum(qg.astype(jnp.float32) * sg, axis=0)
    if scale != 1.0:
        acc = acc * scale
    acc = acc.reshape(*acc.shape[:-2], -1)[..., :last]
    return acc.reshape(shape).astype(dtype)


def _q8_scaled_schedule(base):
    """Schedule generator for a q8 variant: base schedule with global-tier
    Send bytes scaled by Q8_GLOBAL_FACTOR (local tier and writes unchanged)."""

    def gen(topo, m: float, payloads: bool = True):
        sched = base(topo, m, payloads=payloads)
        out = S.Schedule(
            sched.name + "_q8", sched.collective, sched.topo, sched.nbytes,
            root=sched.root,
        )
        for rnd in sched.rounds:
            nr = out.new_round()
            for op in rnd.ops:
                if isinstance(op, S.Send) and not sched.topo.co_located(
                    op.src, op.dst
                ):
                    nr.add(dataclasses.replace(
                        op, nbytes=op.nbytes * Q8_GLOBAL_FACTOR))
                else:
                    nr.add(op)
        return out

    gen.__name__ = base.__name__ + "_q8"
    return gen


# ----------------------------------------------------------------------
# ALL-REDUCE
# ----------------------------------------------------------------------

@register_strategy(
    "all_reduce", "flat", schedule=S.allreduce_flat_ring, impl_tag="flat",
)
def manual_all_reduce_flat(x: jax.Array, mach_axis: str, core_axis: str) -> jax.Array:
    """Hierarchy-oblivious all-reduce: one psum over the joint axes.

    Every proc's full vector crosses whatever links the runtime picks --
    the baseline the paper says existing algorithms default to.
    """
    return lax.psum(x, (mach_axis, core_axis))


@register_strategy(
    "all_reduce", "hier_par", schedule=S.allreduce_hier_par, impl_tag="hier",
)
def manual_all_reduce_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """The paper's all-reduce (allreduce_hier_par schedule family).

    Phase 1 (local):  reduce-scatter over the core axis (Rule 1 reads,
                      cheap tier).
    Phase 2 (global): all-reduce of the 1/c shard over the machine axis --
                      every core drives its machine's external links with a
                      distinct shard simultaneously (Rule 3).
    Phase 3 (local):  all-gather over the core axis (Rule 1 write).
    """
    c = _axis_size(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % c
    flat = jnp.pad(flat, (0, pad))
    s = lax.psum_scatter(flat, core_axis, scatter_dimension=0, tiled=True)
    s = lax.psum(s, mach_axis)
    full = lax.all_gather(s, core_axis, axis=0, tiled=True)
    return full[: x.size].reshape(x.shape)


# The bandwidth-optimal schedule lowers to the same runnable exchange on a
# device mesh (psum_scatter / psum / all_gather); only the modelled local
# tier differs, so it shares the impl under a distinct tag.
register_strategy(
    "all_reduce", "hier_par_bw", schedule=S.allreduce_hier_par_bw,
    impl_tag="hier_bw",
)(manual_all_reduce_hier)


@register_strategy(
    "all_reduce", "hier_par_q8",
    schedule=_q8_scaled_schedule(S.allreduce_hier_par),
    impl_tag="hier_q8", lossy=True, caps=Capabilities(supports_q8=True),
)
def manual_all_reduce_hier_q8(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Hierarchical all-reduce with int8-compressed global tier.

    The machine-tier exchange moves int8 payload + f32 block scales instead
    of full-precision values (lossy; gradient-sync use only).
    """
    c = _axis_size(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % c
    flat = jnp.pad(flat, (0, pad))
    s = lax.psum_scatter(flat, core_axis, scatter_dimension=0, tiled=True)
    q, scale, last = q8_encode(s)
    # Sum of per-machine dequantized contributions: gather both and reduce
    # locally (machine count is small; payload on the wire is compressed).
    qg = lax.all_gather(q, mach_axis, axis=0, tiled=False)
    sg = lax.all_gather(scale, mach_axis, axis=0, tiled=False)
    s = q8_decode_sum(qg, sg, last, s.shape, s.dtype)
    full = lax.all_gather(s, core_axis, axis=0, tiled=True)
    return full[: x.size].reshape(x.shape)


register_strategy(
    "all_reduce", "hier_par_bw_q8",
    schedule=_q8_scaled_schedule(S.allreduce_hier_par_bw),
    impl_tag="hier_bw_q8", lossy=True, caps=Capabilities(supports_q8=True),
)(manual_all_reduce_hier_q8)


# ----------------------------------------------------------------------
# REDUCE-SCATTER  (new: the bandwidth-optimal half of the gradient sync)
# ----------------------------------------------------------------------
#
# Every impl returns the mach-major joint-order shard: device (mach i,
# core j) of an (M, c) mesh ends holding flat-shard index i*c + j of the
# reduced, P-padded vector -- so all strategies are interchangeable and a
# follow-up all-gather over the joint axes reassembles the full result.

def _rs_arranged(x: jax.Array, n_mach: int, n_core: int) -> jax.Array:
    """Flatten + pad to a multiple of P and pre-permute to [c, M, B] order
    so core-then-mach scattering lands mach-major shard i*c+j on (i, j)."""
    P = n_mach * n_core
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % P
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(n_mach, n_core, -1).swapaxes(0, 1).reshape(-1)


@register_strategy(
    "reduce_scatter", "flat", schedule=S.reducescatter_flat_ring,
    impl_tag="flat",
)
def manual_reduce_scatter_flat(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Hierarchy-oblivious reduce-scatter: one psum_scatter over the joint
    axes.  Each proc's full vector rides whatever ring the runtime picks,
    blind to machine seams (the flat-ring strawman)."""
    P = _axis_size(mach_axis) * _axis_size(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % P
    flat = jnp.pad(flat, (0, pad))
    return lax.psum_scatter(
        flat, (mach_axis, core_axis), scatter_dimension=0, tiled=True
    )


@register_strategy(
    "reduce_scatter", "hier_par", schedule=S.reducescatter_hier_par,
    impl_tag="hier",
)
def manual_reduce_scatter_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Two-tier reduce-scatter (reducescatter_hier_par schedule).

    Phase 1 (local, Rule 1):  reduce-scatter over the core axis -- only m/c
             per proc ever faces the machine seam afterwards.
    Phase 2 (global, Rule 3): reduce-scatter of the local shard over the
             machine axis -- all c cores drive their machine's egress links
             with distinct sub-shards simultaneously.
    """
    n_mach = _axis_size(mach_axis)
    n_core = _axis_size(core_axis)
    arr = _rs_arranged(x, n_mach, n_core)
    s = lax.psum_scatter(arr, core_axis, scatter_dimension=0, tiled=True)
    return lax.psum_scatter(s, mach_axis, scatter_dimension=0, tiled=True)


@register_strategy(
    "reduce_scatter", "hier_par_q8",
    schedule=_q8_scaled_schedule(S.reducescatter_hier_par),
    impl_tag="hier_q8", lossy=True, caps=Capabilities(supports_q8=True),
)
def manual_reduce_scatter_hier_q8(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Hierarchical reduce-scatter with int8-compressed global tier.

    Local reduce-scatter runs full-precision (cheap tier); the machine-tier
    exchange is an all_to_all of int8 payload + f32 block scales -- each
    machine sends only the sub-shards the others will own, (M-1)/M of the
    compressed local shard, then dequantize-accumulates what it received
    via the shared ``q8_decode_sum`` path.
    """
    n_mach = _axis_size(mach_axis)
    n_core = _axis_size(core_axis)
    arr = _rs_arranged(x, n_mach, n_core)
    s = lax.psum_scatter(arr, core_axis, scatter_dimension=0, tiled=True)
    sb = s.reshape(n_mach, -1)   # row i = the sub-shard machine i will own
    q, scale, last = q8_encode(sb)
    qx = lax.all_to_all(q, mach_axis, split_axis=0, concat_axis=0, tiled=True)
    sx = lax.all_to_all(
        scale, mach_axis, split_axis=0, concat_axis=0, tiled=True
    )
    return q8_decode_sum(qx, sx, last, sb.shape[1:], s.dtype)


# The flat_q8 schedule prices the flat ring with a compressed global tier;
# on a device mesh it lowers to the same compressed exchange as the
# hierarchical variant (psum_scatter + int8 all_to_all), so it shares the
# impl under a distinct tag -- mirroring the hier_par_bw precedent.
register_strategy(
    "reduce_scatter", "flat_q8",
    schedule=_q8_scaled_schedule(S.reducescatter_flat_ring),
    impl_tag="flat_q8", lossy=True, caps=Capabilities(supports_q8=True),
)(manual_reduce_scatter_hier_q8)


# ----------------------------------------------------------------------
# ALL-TO-ALL
# ----------------------------------------------------------------------

@register_strategy(
    "all_to_all", "flat", schedule=S.alltoall_flat_pairwise, impl_tag="flat",
)
def manual_all_to_all_flat(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Flat all-to-all over the joint (mach, core) axes.

    x: [P, ...] where P = n_mach * n_core; chunk j goes to global proc j.
    """
    # split the leading dim over both axes: [M, C, ...]
    n_mach = _axis_size(mach_axis)
    n_core = _axis_size(core_axis)
    xm = x.reshape(n_mach, n_core, *x.shape[1:])
    xm = lax.all_to_all(xm, mach_axis, split_axis=0, concat_axis=0, tiled=False)
    xm = lax.all_to_all(xm, core_axis, split_axis=1, concat_axis=1, tiled=False)
    return xm.reshape(n_mach * n_core, *x.shape[1:])


@register_strategy(
    "all_to_all", "hier_par", schedule=S.alltoall_hier_par, impl_tag="hier",
)
def manual_all_to_all_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Kumar-style two-tier all-to-all (alltoall_hier_par schedule).

    Phase 1: local all-to-all consolidates per-destination-machine bundles
             onto the egress cores (cheap tier).
    Phase 2: one machine-tier all-to-all of consolidated bundles, all egress
             links in parallel (Rule 3).
    Phase 3: local all-to-all scatters received bundles to their final cores
             (Rule 1 writes in the model; an ICI shuffle on TPU).

    Same bytes as flat on the global tier but M-1 consolidated transfers per
    machine instead of P-1 small ones, and no duplicate DCN crossings.
    """
    n_mach = _axis_size(mach_axis)
    n_core = _axis_size(core_axis)
    payload = x.shape[1:]
    xm = x.reshape(n_mach, n_core, *payload)  # [dst_mach, dst_core, ...]
    # Global phase: one machine-tier exchange of consolidated bundles --
    # each core crosses the DCN exactly once per destination machine
    # (consolidation; Rule 3 keeps every core's link busy simultaneously).
    xm = lax.all_to_all(xm, mach_axis, split_axis=0, concat_axis=0, tiled=True)
    # now [src_mach, dst_core, ...]; rows came from (src_mach, my_core)
    # Local phase: core-tier shuffle to final destinations (cheap tier;
    # a shared-memory write in the paper's model, an ICI shuffle on TPU).
    xm = lax.all_to_all(xm, core_axis, split_axis=1, concat_axis=0, tiled=True)
    # now [src_core * src_mach, 1, ...] -- reorder to source-major layout
    xm = xm.reshape(n_core, n_mach, *payload)
    xm = jnp.swapaxes(xm, 0, 1)
    return xm.reshape(n_mach * n_core, *payload)


# ----------------------------------------------------------------------
# ALL-GATHER  (new in the registry redesign: costed AND runnable)
# ----------------------------------------------------------------------

@register_strategy(
    "all_gather", "flat", schedule=S.allgather_flat_ring, impl_tag="flat",
)
def manual_all_gather_flat(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Hierarchy-oblivious all-gather over the joint axes.

    Every proc's shard circulates over whatever links the runtime picks;
    result is the concatenation over global proc order (mach-major).
    """
    return lax.all_gather(x, (mach_axis, core_axis), axis=0, tiled=True)


@register_strategy(
    "all_gather", "hier_par", schedule=S.allgather_hier_par, impl_tag="hier",
)
def manual_all_gather_hier(
    x: jax.Array, mach_axis: str, core_axis: str
) -> jax.Array:
    """Two-tier all-gather (allgather_hier_par schedule).

    Phase 1 (global, Rule 3): every proc ring-exchanges its OWN m-byte shard
             across the machine axis -- all c cores drive their machine's
             egress links at once, so the DCN carries each machine block
             exactly once, striped 1/c per link.
    Phase 2 (local, Rule 1):  clique all-gather over the core axis fans the
             per-machine stacks out to every co-located proc.

    Result rows are ordered by global proc id (machine-major), matching the
    schedule's semantics check.
    """
    n_mach = _axis_size(mach_axis)
    n_core = _axis_size(core_axis)
    g = lax.all_gather(x, mach_axis, axis=0, tiled=False)    # [M, ...]
    full = lax.all_gather(g, core_axis, axis=1, tiled=False)  # [M, c, ...]
    return full.reshape(n_mach * n_core * x.shape[0], *x.shape[1:])


# ----------------------------------------------------------------------
# BROADCAST  (new in the registry redesign: costed AND runnable)
# ----------------------------------------------------------------------

@register_strategy(
    "broadcast", "flat", schedule=S.bcast_flat_binomial, impl_tag="flat",
    caps=Capabilities(needs_root=True),
)
def manual_broadcast_flat(
    x: jax.Array, mach_axis: str, core_axis: str, root: int = 0
) -> jax.Array:
    """Hierarchy-oblivious broadcast: mask to the root and psum everywhere.

    The root's full shard crosses the joint axes blind to machine seams --
    the runnable twin of the binomial-tree strawman.
    """
    c = _axis_size(core_axis)
    me = lax.axis_index(mach_axis) * c + lax.axis_index(core_axis)
    masked = jnp.where(me == root, x, jnp.zeros_like(x))
    return lax.psum(masked, (mach_axis, core_axis))


@register_strategy(
    "broadcast", "hier_par", schedule=S.bcast_hier_par, impl_tag="hier",
    caps=Capabilities(needs_root=True),
)
def manual_broadcast_hier(
    x: jax.Array, mach_axis: str, core_axis: str, root: int = 0
) -> jax.Array:
    """The paper's broadcast (bcast_hier_par schedule), runnable.

    Phase 1 (local, Rule 1 write): the root publishes inside its machine so
             every co-located core holds the value.
    Phase 2 (global, Rule 3):      core k of the root machine sends stripe k
             (1/c of the vector) across the machine axis -- degree-parallel
             egress, each DCN link carrying a distinct stripe.
    Phase 3 (local, Rule 1):       cores all-gather the stripes.
    """
    c = _axis_size(core_axis)
    root_mach, root_core = divmod(root, c)
    mach = lax.axis_index(mach_axis)
    core = lax.axis_index(core_axis)
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % c
    flat = jnp.pad(flat, (0, pad))
    is_root = jnp.logical_and(mach == root_mach, core == root_core)
    mine = jnp.where(is_root, flat, jnp.zeros_like(flat))
    # Phase 1: within the root machine every core obtains the full vector;
    # other machines hold zeros and contribute nothing later.
    local = lax.psum(mine, core_axis)
    # Phase 2: each core keeps its 1/c stripe and crosses the machine tier
    # with it; the psum sums one real stripe with zeros from non-root
    # machines, i.e. a pure parallel-egress transfer.
    stripes = local.reshape(c, -1)
    stripe = lax.dynamic_index_in_dim(stripes, core, axis=0, keepdims=False)
    stripe = lax.psum(stripe, mach_axis)
    # Phase 3: reassemble locally.
    full = lax.all_gather(stripe, core_axis, axis=0, tiled=True)
    return full[: x.size].reshape(x.shape)


# The single-leader hierarchical broadcast is the paper's "previous
# approaches" strawman: costed for comparison tables, never run.  This is
# the strategy the seed planner would happily emit an impl tag for with no
# implementation behind it.
register_model_only(
    "broadcast", "hier_seq", schedule=S.bcast_hier_seq,
    caps=Capabilities(needs_root=True),
    doc="single-leader hierarchical broadcast (model-only strawman)",
)


# ----------------------------------------------------------------------
# GATHER  (model-only: the paper costs it for the C2 asymmetry claim; a
# runnable rooted gather has no production consumer yet)
# ----------------------------------------------------------------------

register_model_only(
    "gather", "flat", schedule=S.gather_flat_binomial,
    caps=Capabilities(needs_root=True),
    doc="inverse binomial tree to root, hierarchy-oblivious",
)
register_model_only(
    "gather", "hier_par", schedule=S.gather_hier_par,
    caps=Capabilities(needs_root=True),
    doc="clique-read local combine + parallel ingress (paper C2)",
)
