"""Single source of truth for collective strategies.

The seed realized the paper's "cost model selects the schedule" loop as three
loosely-coupled string-keyed dicts (``schedules.GENERATORS``,
``planner._IMPL_OF_STRATEGY``, ``collectives.MANUAL_ALL_REDUCE``) that could
silently drift: the planner would return an ``impl`` tag with no runnable
implementation behind it.  This module collapses them into one registry of
``CollectiveSpec`` entries, each binding -- per (collective, strategy) --

  * the *schedule generator* (the costable object the simulator times),
  * the *runnable implementation* (a shard_map-region function), or an
    explicit ``model_only`` marker when a strategy exists purely for the
    cost model (e.g. the single-leader strawman ``hier_seq``),
  * a ``lossy`` flag (int8-compressed tiers) and capability metadata
    (needs a root, minimum mesh shape, q8 support).

``validate_registry`` is called at ``repro.comm`` import time: every
plannable strategy is guaranteed executable or explicitly model-only, so the
planner can never again emit a plan nothing can run.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable


class RegistryError(ValueError):
    """Raised when the strategy registry is inconsistent."""


@dataclass(frozen=True)
class Capabilities:
    """Static capability metadata for one strategy.

    needs_root:             the collective is rooted (broadcast / gather);
                            schedule generators take ``root=`` and runnable
                            impls take a ``root`` argument.
    supports_q8:            the global tier may carry int8 payloads.
    min_machines:           smallest machine count the strategy supports.
    min_procs_per_machine:  smallest per-machine proc count it supports.
    """

    needs_root: bool = False
    supports_q8: bool = False
    min_machines: int = 1
    min_procs_per_machine: int = 1


@dataclass(frozen=True)
class CollectiveSpec:
    """One (collective, strategy) binding: costable schedule + runnable impl.

    schedule:  ``f(topo, m, *, root=0, payloads=True) -> Schedule`` for rooted
               collectives, ``f(topo, m, *, payloads=True) -> Schedule``
               otherwise (see ``build_schedule``).
    impl:      function runnable inside a shard_map region over a
               ("mach", "core") mesh -- ``f(x, mach_axis, core_axis)``, plus
               ``root=`` when ``caps.needs_root`` -- or None for model-only
               strategies.
    impl_tag:  short runtime tag carried by ``Plan.impl`` (stable across the
               legacy ``MANUAL_ALL_REDUCE`` keys); None for model-only.
    """

    collective: str
    strategy: str
    schedule: Callable
    impl: Callable | None = None
    impl_tag: str | None = None
    lossy: bool = False
    model_only: bool = False
    caps: Capabilities = field(default_factory=Capabilities)
    doc: str = ""

    @property
    def executable(self) -> bool:
        return self.impl is not None

    def __post_init__(self) -> None:
        if not callable(self.schedule):
            raise RegistryError(
                f"{self.collective}/{self.strategy}: schedule not callable"
            )
        if self.impl is None and not self.model_only:
            raise RegistryError(
                f"{self.collective}/{self.strategy}: no runnable impl and not "
                "marked model_only -- a plannable strategy must be executable "
                "or explicitly model-only"
            )
        if self.impl is not None and self.model_only:
            raise RegistryError(
                f"{self.collective}/{self.strategy}: impl given but marked "
                "model_only"
            )
        if self.impl is not None and not callable(self.impl):
            raise RegistryError(
                f"{self.collective}/{self.strategy}: impl {self.impl!r} is "
                "not callable"
            )
        if self.executable and not self.impl_tag:
            raise RegistryError(
                f"{self.collective}/{self.strategy}: executable spec needs "
                "an impl_tag"
            )

    def supports(self, topo) -> bool:
        """Whether the strategy can run/cost on this topology at all."""
        return (
            topo.n_machines >= self.caps.min_machines
            and topo.procs_per_machine >= self.caps.min_procs_per_machine
        )

    def build_schedule(self, topo, m: float, root: int = 0,
                       payloads: bool = True):
        """Build the costable schedule, handling rooted-ness uniformly."""
        if self.caps.needs_root:
            return self.schedule(topo, m, root=root, payloads=payloads)
        return self.schedule(topo, m, payloads=payloads)


_REGISTRY: dict[tuple[str, str], CollectiveSpec] = {}


def register(spec: CollectiveSpec) -> CollectiveSpec:
    key = (spec.collective, spec.strategy)
    if key in _REGISTRY:
        raise RegistryError(f"duplicate registration for {key}")
    _REGISTRY[key] = spec
    return spec


def register_strategy(
    collective: str,
    strategy: str,
    *,
    schedule: Callable,
    impl_tag: str | None = None,
    lossy: bool = False,
    caps: Capabilities | None = None,
    doc: str = "",
) -> Callable:
    """Decorator: register ``fn`` as the runnable impl of a strategy.

    >>> @register_strategy("all_reduce", "hier_par_bw",
    ...                    schedule=S.allreduce_hier_par_bw, impl_tag="hier_bw")
    ... def manual_all_reduce_hier(x, mach_axis, core_axis): ...
    """

    def deco(fn: Callable) -> Callable:
        register(
            CollectiveSpec(
                collective=collective,
                strategy=strategy,
                schedule=schedule,
                impl=fn,
                impl_tag=impl_tag or strategy,
                lossy=lossy,
                caps=caps or Capabilities(),
                doc=doc or (fn.__doc__ or "").strip().split("\n")[0],
            )
        )
        return fn

    return deco


def register_model_only(
    collective: str,
    strategy: str,
    *,
    schedule: Callable,
    caps: Capabilities | None = None,
    doc: str = "",
) -> CollectiveSpec:
    """Register a strategy that exists only for the cost model.

    The planner will still cost it (for tables and what-if analysis) but
    ``CommContext.plan`` excludes it from executable selection, and calling
    its ``PlannedCollective`` raises.
    """
    return register(
        CollectiveSpec(
            collective=collective,
            strategy=strategy,
            schedule=schedule,
            impl=None,
            impl_tag=None,
            model_only=True,
            caps=caps or Capabilities(),
            doc=doc,
        )
    )


# ----------------------------------------------------------------------
# Queries / derived views
# ----------------------------------------------------------------------

def get_spec(collective: str, strategy: str) -> CollectiveSpec:
    try:
        return _REGISTRY[(collective, strategy)]
    except KeyError:
        known = sorted(s for c, s in _REGISTRY if c == collective)
        raise RegistryError(
            f"no strategy {strategy!r} for collective {collective!r} "
            f"(known: {known})"
        ) from None


def collectives() -> list[str]:
    return sorted({c for c, _ in _REGISTRY})


def specs(
    collective: str | None = None,
    *,
    executable_only: bool = False,
    include_lossy: bool = True,
) -> list[CollectiveSpec]:
    out = [
        sp
        for sp in _REGISTRY.values()
        if (collective is None or sp.collective == collective)
        and (not executable_only or sp.executable)
        and (include_lossy or not sp.lossy)
    ]
    return sorted(out, key=lambda sp: (sp.collective, sp.strategy))


def strategies(collective: str, *, lossy_ok: bool = False,
               executable_only: bool = False) -> list[str]:
    return [
        sp.strategy
        for sp in specs(collective, executable_only=executable_only,
                        include_lossy=lossy_ok)
    ]


def generators_view() -> dict[str, dict[str, Callable]]:
    """The legacy ``schedules.GENERATORS`` shape, derived from the registry.

    Lossless strategies only, matching the seed dict: lossy (q8) variants
    were never in GENERATORS -- their schedules are derived by scaling the
    base schedule's global-tier bytes.
    """
    out: dict[str, dict[str, Callable]] = {}
    for sp in specs(include_lossy=False):
        out.setdefault(sp.collective, {})[sp.strategy] = sp.schedule
    return out


def executable_view(collective: str) -> dict[str, Callable]:
    """Legacy ``MANUAL_ALL_REDUCE`` shape: impl_tag -> runnable fn."""
    return {
        sp.impl_tag: sp.impl
        for sp in specs(collective, executable_only=True)
    }


def executable_pairs() -> list[tuple[str, str]]:
    """Every registered (collective, strategy) that can actually run."""
    return [(sp.collective, sp.strategy) for sp in specs(executable_only=True)]


def resolve_impl(collective: str, impl_tag: str) -> Callable:
    """impl tag -> runnable fn; raises RegistryError for unknown tags."""
    for sp in specs(collective, executable_only=True):
        if sp.impl_tag == impl_tag:
            return sp.impl
    raise RegistryError(f"no runnable impl {impl_tag!r} for {collective!r}")


def _smoke_topologies():
    """Small 2- and 3-tier instances every strategy must plan on."""
    from repro.core.topology import ClusterTopology, LinkTier

    shm = LinkTier("shm", alpha=1e-6, beta=1e-9)
    mid = LinkTier("mid", alpha=2e-6, beta=2e-9)
    eth = LinkTier("eth", alpha=1e-5, beta=1e-8)
    return (
        ClusterTopology(
            tiers=(shm, eth), fanout=(2, 2), degree=1,
            write_cost=1e-6, assemble_cost=1e-6,
        ),
        ClusterTopology(
            tiers=(shm, mid, eth), fanout=(2, 2, 2), degree=2,
            write_cost=1e-6, assemble_cost=1e-6,
        ),
    )


def validate_registry(regs: Iterable[CollectiveSpec] | None = None) -> None:
    """Import-time consistency check over the whole registry.

    * every executable spec has a callable impl and a unique impl_tag within
      its collective;
    * every non-executable spec is explicitly model_only (also enforced at
      construction -- this re-checks after any manual mutation);
    * every collective exposes at least one executable, lossless strategy
      (the planner must always be able to return something runnable);
    * rooted-ness metadata is uniform within a collective;
    * every strategy's schedule builds, validates, and passes its semantics
      check on BOTH a two-tier and a three-tier topology instance -- the
      tier-hierarchy generalization can never leave a strategy behind.
    """
    regs = list(regs) if regs is not None else list(_REGISTRY.values())
    if not regs:
        raise RegistryError("empty strategy registry")
    by_coll: dict[str, list[CollectiveSpec]] = {}
    for sp in regs:
        by_coll.setdefault(sp.collective, []).append(sp)
        if not sp.executable and not sp.model_only:
            raise RegistryError(
                f"{sp.collective}/{sp.strategy}: plannable but not runnable"
            )
    for coll, group in by_coll.items():
        tags = [sp.impl_tag for sp in group if sp.executable]
        if len(tags) != len(set(tags)):
            raise RegistryError(f"{coll}: duplicate impl tags {tags}")
        if not any(sp.executable and not sp.lossy for sp in group):
            # gather is the one deliberate exception: the paper costs it
            # (C2 asymmetry) but no runnable impl exists yet -- it must be
            # explicitly all-model-only, not silently impl-less.
            if not all(sp.model_only for sp in group):
                raise RegistryError(
                    f"{coll}: no lossless executable strategy and not all "
                    "model-only"
                )
        rooted = {sp.caps.needs_root for sp in group}
        if len(rooted) != 1:
            raise RegistryError(f"{coll}: inconsistent needs_root metadata")
    from repro.core.simulator import check_semantics, validate

    for topo in _smoke_topologies():
        for sp in regs:
            if not sp.supports(topo):
                continue
            try:
                sched = sp.build_schedule(topo, 1024.0, payloads=True)
                validate(sched)
                if not sp.lossy:
                    # q8 variants are byte-scaled twins of checked
                    # schedules; the volume bounds in check_semantics are
                    # deliberately below their compressed global bytes.
                    check_semantics(sched)
            except Exception as e:
                raise RegistryError(
                    f"{sp.collective}/{sp.strategy} does not plan on the "
                    f"{topo.n_tiers}-tier {'x'.join(map(str, topo.fanout))} "
                    f"smoke topology: {e}"
                ) from e
