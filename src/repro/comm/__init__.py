"""``repro.comm`` -- the collectives API: one registry, plan, then run.

A *collective* is a first-class object here: a ``CollectiveSpec`` binds, per
(collective, strategy), the costable schedule generator, the runnable
shard_map implementation, a lossy flag, and capability metadata.  The
registry is the single source of truth the legacy ``schedules.GENERATORS``,
``planner._IMPL_OF_STRATEGY`` and ``collectives.MANUAL_ALL_REDUCE`` dicts
are now derived from, and it is validated at import time: every plannable
strategy is executable or explicitly model-only.

Typical use::

    from repro import comm
    from repro.core.topology import tpu_v5e_cluster

    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    pc = ctx.plan("all_reduce", nbytes=1e9, lossy_ok=True)
    pc.plan.t_rounds       # modelled time under the paper's round model
    y = pc(x)              # inside a shard_map region over (mach, core)
    ctx.cost_table("all_reduce", 1e9)   # every strategy, costed

The old free-function surface (``repro.core.make_policy`` / ``best_plan`` /
``pod_sync_grads``) remains as thin deprecation shims over this package.
"""

from . import impls as _impls  # noqa: F401  (registers all strategies)
from .bucketing import (  # noqa: F401
    BucketedChoice,
    BucketLayout,
    OverlapChoice,
    choose_n_chunks,
    choose_overlap,
    pack_buckets,
    plan_buckets,
    unpack_buckets,
)
from .calibrate import (  # noqa: F401
    CALIBRATION_ENV,
    CalibrationResult,
    FitResult,
    Measurement,
    calibrate,
    calibrated_cluster,
    fit_calibration,
    fit_topology,
    load_calibration,
    measure_strategy,
    probe_collectives,
    save_calibration,
)
from .context import (  # noqa: F401
    CommContext,
    ModelOnlyStrategyError,
    Plan,
    PlannedCollective,
    best_plan,
    enumerate_plans,
    plan_for_spec,
)
from .grad_sync import (  # noqa: F401
    LOSSY_POD_SYNC_FORMATS,
    POD_SYNC_FORMATS,
    PodSyncDecision,
    bucket_combiner,
    plan_pod_sync,
    pod_combine,
    pod_combine_flat,
    pod_combine_microbatched,
    pod_combine_q8,
    pod_sync_builder,
    pod_sync_grads,
    pod_sync_topology,
    select_pod_sync,
)
from .health import (  # noqa: F401
    ReplanMonitor,
    RetryPolicy,
    StepWatchdog,
    retry_with_backoff,
)
from .impls import (  # noqa: F401
    Q8_BLOCK,
    Q8_GLOBAL_FACTOR,
    q8_decode,
    q8_decode_sum,
    q8_encode,
)
from .registry import (  # noqa: F401
    Capabilities,
    CollectiveSpec,
    RegistryError,
    collectives,
    executable_pairs,
    executable_view,
    generators_view,
    get_spec,
    register_model_only,
    register_strategy,
    resolve_impl,
    specs,
    strategies,
    validate_registry,
)

# Import-time guarantee: the planner can never emit a plan nothing can run.
validate_registry()
