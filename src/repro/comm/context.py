"""Registry-backed planning: ``CommContext`` -> ``PlannedCollective``.

The paper's punchline is that a cost model should *select* the collective
schedule per topology and message size.  This module is the selection layer,
rebuilt on the strategy registry so a plan is always backed by the spec that
can run it:

    ctx = CommContext(tpu_v5e_cluster(n_pods=2))
    pc = ctx.plan("all_reduce", nbytes=1e9, lossy_ok=True)
    pc.plan.t_rounds          # modelled seconds under the round model
    y = pc(x)                 # callable inside a shard_map region

Costing exploits that every generator's round-based time is exactly affine
in the message size m (each op's bytes is an integer multiple of m):
``t(m) = A + B*m``.  We evaluate the schedule at two message sizes once per
(topology, collective, strategy, root) and cache the coefficients, so
planning is O(1) per query even for 512-chip topologies.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

from repro.core.simulator import affine_time
from repro.core.topology import ClusterTopology

from . import registry
from .registry import CollectiveSpec


class ModelOnlyStrategyError(RuntimeError):
    """Raised when a model-only PlannedCollective is called."""


@dataclass(frozen=True)
class Plan:
    """One costed decision record: what to run and what the model expects.

    ``impl`` is the runnable implementation tag (resolvable through the
    registry) or None for model-only strategies -- the planner can still
    cost those for tables, but they are excluded from executable selection.
    """

    collective: str
    strategy: str
    impl: str | None
    nbytes: float
    t_rounds: float
    n_rounds: int
    global_bytes: float
    local_bytes: float
    lossy: bool = False
    model_only: bool = False
    root: int = 0

    def speedup_vs(self, other: "Plan") -> float:
        return other.t_rounds / self.t_rounds


@lru_cache(maxsize=4096)
def _affine_cost(
    topo: ClusterTopology, collective: str, strategy: str, root: int
) -> tuple:
    """(A, B, n_rounds, gB, lB) with t(m) = A + B*m, global/local bytes = m*(gB, lB)."""
    spec = registry.get_spec(collective, strategy)
    m1, built = 1024.0, {}

    def build(m: float):
        if m not in built:
            built[m] = spec.build_schedule(topo, m, root=root, payloads=False)
        return built[m]

    A, B = affine_time(build, m1=m1)
    s1 = build(m1)
    return (
        A, B, s1.n_rounds,
        s1.total_global_bytes() / m1, s1.total_local_bytes() / m1,
    )


def plan_for_spec(
    topo: ClusterTopology, spec: CollectiveSpec, nbytes: float, root: int = 0
) -> Plan:
    A, B, n_rounds, gB, lB = _affine_cost(
        topo, spec.collective, spec.strategy, root if spec.caps.needs_root else 0
    )
    return Plan(
        collective=spec.collective,
        strategy=spec.strategy,
        impl=spec.impl_tag,
        nbytes=nbytes,
        t_rounds=A + B * nbytes,
        n_rounds=n_rounds,
        global_bytes=gB * nbytes,
        local_bytes=lB * nbytes,
        lossy=spec.lossy,
        model_only=spec.model_only,
        root=root,
    )


def enumerate_plans(
    topo: ClusterTopology,
    collective: str,
    nbytes: float,
    root: int = 0,
    lossy_ok: bool = False,
    executable_only: bool = False,
) -> list[Plan]:
    """All candidate plans for a collective, sorted by modelled time."""
    if not 0 <= root < topo.n_procs:
        raise ValueError(
            f"root {root} out of range for a {topo.n_machines}x"
            f"{topo.procs_per_machine} topology ({topo.n_procs} procs)"
        )
    plans = [
        plan_for_spec(topo, spec, nbytes, root=root)
        for spec in registry.specs(
            collective, executable_only=executable_only, include_lossy=lossy_ok
        )
        if spec.supports(topo)
    ]
    if not plans:
        raise registry.RegistryError(
            f"no strategies for {collective!r} on {topo.n_machines}x"
            f"{topo.procs_per_machine} (lossy_ok={lossy_ok}, "
            f"executable_only={executable_only})"
        )
    plans.sort(key=lambda p: p.t_rounds)
    return plans


def best_plan(
    topo: ClusterTopology,
    collective: str,
    nbytes: float,
    root: int = 0,
    lossy_ok: bool = False,
    executable_only: bool = False,
) -> Plan:
    return enumerate_plans(
        topo, collective, nbytes, root, lossy_ok, executable_only
    )[0]


# ----------------------------------------------------------------------
# The user-facing API: a context binds topology + mesh axis names once
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class PlannedCollective:
    """A plan fused to its runnable implementation.

    Directly callable inside a ``shard_map``/``vmap`` region over the
    context's (mach, core) mesh axes; carries its cost-model record in
    ``plan`` and its registry binding in ``spec``.
    """

    plan: Plan
    spec: CollectiveSpec
    mach_axis: str
    core_axis: str

    @property
    def executable(self) -> bool:
        return self.spec.executable

    def __call__(self, x, **overrides):
        if not self.executable:
            raise ModelOnlyStrategyError(
                f"{self.spec.collective}/{self.spec.strategy} is model-only: "
                "it can be costed but not run; plan with "
                "executable_only=True (the default) for a runnable schedule"
            )
        kw = dict(mach_axis=self.mach_axis, core_axis=self.core_axis)
        if self.spec.caps.needs_root:
            kw["root"] = self.plan.root
        kw.update(overrides)
        return self.spec.impl(x, **kw)

    def describe(self) -> str:
        p = self.plan
        run = p.impl if self.executable else "model-only"
        return (
            f"{p.collective}/{p.strategy} [{run}] m={p.nbytes:.3g}B "
            f"t={p.t_rounds * 1e6:.1f}us rounds={p.n_rounds} "
            f"global={p.global_bytes:.3g}B local={p.local_bytes:.3g}B"
            + (" (lossy)" if p.lossy else "")
        )


class CommContext:
    """Planning + execution surface for one cluster topology.

    >>> ctx = CommContext(tpu_v5e_cluster(n_pods=2))
    >>> pc = ctx.plan("all_reduce", grad_bytes, lossy_ok=True)
    >>> synced = shard_map_region_fn(pc)          # pc is callable in-region
    >>> ctx.cost_table("all_reduce", grad_bytes)  # every strategy, costed

    ``mach_axis`` / ``core_axis`` name the mesh axes the runnable impls
    operate over (the paper's machine / in-machine process tiers).
    """

    def __init__(
        self,
        topo: ClusterTopology,
        *,
        mach_axis: str = "mach",
        core_axis: str = "core",
    ) -> None:
        self.topo = topo
        self.mach_axis = mach_axis
        self.core_axis = core_axis

    def __repr__(self) -> str:
        return (
            f"CommContext({'x'.join(map(str, reversed(self.topo.fanout)))}, "
            f"degree={self.topo.degree}, "
            f"axes=({self.mach_axis!r}, {self.core_axis!r}))"
        )

    def _bind(self, plan: Plan) -> PlannedCollective:
        spec = registry.get_spec(plan.collective, plan.strategy)
        return PlannedCollective(
            plan=plan, spec=spec,
            mach_axis=self.mach_axis, core_axis=self.core_axis,
        )

    def plan(
        self,
        collective: str,
        nbytes: float,
        *,
        root: int = 0,
        lossy_ok: bool = False,
        executable_only: bool = True,
    ) -> PlannedCollective:
        """Best modelled strategy, bound to its runnable implementation.

        By default only executable strategies compete (the returned object
        must be callable); pass ``executable_only=False`` to let model-only
        strategies win for analysis purposes.
        """
        p = best_plan(
            self.topo, collective, nbytes, root=root,
            lossy_ok=lossy_ok, executable_only=executable_only,
        )
        return self._bind(p)

    def plan_bucketed(
        self,
        collective: str,
        nbytes: float,
        *,
        strategy: str | None = None,
        root: int = 0,
        lossy_ok: bool = False,
        min_bucket_bytes: int | None = None,
        max_chunks: int | None = None,
    ):
        """Bucket-size sweep under the pipelined cost view.

        Picks the strategy exactly like ``plan`` (unless pinned via
        ``strategy``), then sweeps chunk counts with
        ``bucketing.choose_n_chunks``: the message is cut into n equal
        buckets and chunk k+1's local stage overlaps chunk k's global
        stage (``simulate_pipelined``).  Returns a
        ``bucketing.BucketedChoice`` whose ``n_chunks``/``bucket_bytes``
        the fitted alpha/beta chose -- the latency-amortization vs
        pipeline-fill tradeoff, computed instead of folklore.
        """
        from . import bucketing

        if strategy is None:
            strategy = best_plan(
                self.topo, collective, nbytes, root=root, lossy_ok=lossy_ok,
                executable_only=True,
            ).strategy
        spec = registry.get_spec(collective, strategy)
        kw = {}
        if min_bucket_bytes is not None:
            kw["min_bucket_bytes"] = min_bucket_bytes
        if max_chunks is not None:
            kw["max_chunks"] = max_chunks
        return bucketing.choose_n_chunks(
            lambda m: spec.build_schedule(
                self.topo, m, root=root, payloads=False
            ),
            nbytes,
            **kw,
        )

    def plans(
        self,
        collective: str,
        nbytes: float,
        *,
        root: int = 0,
        lossy_ok: bool = False,
        executable_only: bool = False,
    ) -> list[PlannedCollective]:
        return [
            self._bind(p)
            for p in enumerate_plans(
                self.topo, collective, nbytes, root=root,
                lossy_ok=lossy_ok, executable_only=executable_only,
            )
        ]

    # ------------------------------------------------------------------
    # calibration: build from measurements, confront the model with them
    # ------------------------------------------------------------------

    @classmethod
    def from_calibration(
        cls,
        source,
        *,
        n_machines: int | None = None,
        procs_per_machine: int | None = None,
        degree: int | None = None,
        fanout=None,
        mach_axis: str = "mach",
        core_axis: str = "core",
    ) -> "CommContext":
        """Context over an empirically fitted topology.

        ``source`` is a ``calibrate.CalibrationResult`` or a path to a
        calibration JSON written by ``calibrate.save_calibration``.  The
        shape overrides transplant the fitted link tiers onto a different
        cluster shape (e.g. calibrate on a 2x4 fake mesh, plan for 2x256
        pods); ``fanout`` replaces the whole tier hierarchy's extents.
        """
        from .calibrate import (
            CalibrationResult,
            calibrated_cluster,
            load_calibration,
        )

        calib = (
            source
            if isinstance(source, CalibrationResult)
            else load_calibration(source)
        )
        topo = calibrated_cluster(
            calib,
            n_machines=n_machines,
            procs_per_machine=procs_per_machine,
            degree=degree,
            fanout=fanout,
        )
        return cls(topo, mach_axis=mach_axis, core_axis=core_axis)

    def _topo_for(self, ms) -> ClusterTopology:
        """This context's parameters on the measurement's probe shape."""
        topo = self.topo
        fanout = getattr(ms, "fanout", None)
        shape = getattr(ms, "shape", None)
        if fanout:
            degree = shape[2] if shape else topo.degree
            if (tuple(fanout), degree) != (topo.fanout, topo.degree):
                topo = topo.with_shape(fanout, degree)
        elif shape and tuple(shape) != (
            topo.n_machines, topo.procs_per_machine, topo.degree
        ):
            topo = topo.with_(
                n_machines=shape[0], procs_per_machine=shape[1],
                degree=shape[2],
            )
        return topo

    def validate_against_measurements(self, measurements) -> list[dict]:
        """Modelled-vs-measured error per probe, under THIS context's model.

        ``measurements`` is an iterable of ``calibrate.Measurement`` (or any
        object with collective/strategy/nbytes/t_measured attributes).  Each
        probe is modelled on its own recorded shape with this context's tier
        parameters.  ``rel_error`` is signed: positive means the model
        over-predicts.
        """
        rows = []
        for ms in measurements:
            spec = registry.get_spec(ms.collective, ms.strategy)
            p = plan_for_spec(
                self._topo_for(ms), spec, ms.nbytes,
                root=getattr(ms, "root", 0),
            )
            rows.append(
                dict(
                    collective=ms.collective,
                    strategy=ms.strategy,
                    nbytes=ms.nbytes,
                    shape=getattr(ms, "shape", None),
                    t_measured=ms.t_measured,
                    t_modelled=p.t_rounds,
                    rel_error=(p.t_rounds - ms.t_measured) / ms.t_measured,
                )
            )
        return rows

    def crossover_table(self, measurements) -> list[dict]:
        """Empirically best vs model-chosen strategy per (collective, nbytes).

        Buckets the measurements (per probe shape), then reports for each
        bucket the strategy with the best *measured* time, the strategy THIS
        context's model ranks first among the measured candidates, and the
        regret: measured time of the model's pick over the best measured
        time (1.0 = the model chose optimally, regardless of absolute-time
        error).
        """
        buckets: dict[tuple, list] = {}
        for ms in measurements:
            shape = getattr(ms, "shape", None)
            key = (ms.collective, ms.nbytes, tuple(shape) if shape else None)
            buckets.setdefault(key, []).append(ms)
        rows = []
        for (coll, nbytes, shape), group in sorted(
            buckets.items(), key=lambda kv: (kv[0][0], kv[0][1], str(kv[0][2]))
        ):
            t_model = {
                ms.strategy: plan_for_spec(
                    self._topo_for(ms),
                    registry.get_spec(coll, ms.strategy),
                    nbytes,
                    root=getattr(ms, "root", 0),
                ).t_rounds
                for ms in group
            }
            measured_best = min(group, key=lambda ms: ms.t_measured)
            model_pick = min(group, key=lambda ms: t_model[ms.strategy])
            rows.append(
                dict(
                    collective=coll,
                    nbytes=nbytes,
                    shape=shape,
                    measured_best=measured_best.strategy,
                    modelled_best=model_pick.strategy,
                    agree=measured_best.strategy == model_pick.strategy,
                    t_measured_best=measured_best.t_measured,
                    t_measured_of_pick=model_pick.t_measured,
                    regret=model_pick.t_measured / measured_best.t_measured,
                )
            )
        return rows

    def cost_table(
        self,
        collective: str,
        nbytes: float,
        *,
        root: int = 0,
        lossy_ok: bool = True,
    ) -> list[dict]:
        """Every registered strategy costed at ``nbytes``, best first."""
        rows = []
        for pc in self.plans(collective, nbytes, root=root, lossy_ok=lossy_ok):
            p = pc.plan
            rows.append(
                dict(
                    collective=p.collective,
                    strategy=p.strategy,
                    impl=p.impl,
                    executable=pc.executable,
                    lossy=p.lossy,
                    t_us=p.t_rounds * 1e6,
                    n_rounds=p.n_rounds,
                    global_bytes=p.global_bytes,
                    local_bytes=p.local_bytes,
                )
            )
        return rows
