"""Gradient bucketing: fixed-byte buckets + cost-model-chosen bucket size.

The trainer's pod-tier sync shipped the whole gradient as one monolithic
exchange: full serialization, zero overlap between the local (shared-memory
/ ICI) tier and the global (DCN) tier.  This module supplies the two halves
of the bucketed, pipelined alternative:

1. **Tree <-> buckets.**  ``plan_buckets`` flattens a gradient pytree into
   contiguous fixed-byte buckets.  Leaves are grouped by (dtype, sharding
   key) -- a bucket never mixes dtypes or intra-pod layouts -- then each
   group's leaves are concatenated into one flat vector and split at fixed
   byte boundaries, so every bucket except a group's last has exactly the
   requested size (leaves are split mid-tensor when they straddle a
   boundary; ``unpack_buckets`` reassembles them exactly).

2. **Bucket-size selection.**  ``choose_n_chunks`` prices the chunked
   schedule under ``simulate_pipelined``: small buckets fill the pipeline
   (more overlap between round k's local combine and round k+1's global
   send) but pay the per-message alpha once per bucket; large buckets
   amortize alpha but serialize the tiers.  With PR 2's fitted per-tier
   alpha/beta the crossover is computed, not folklore.  Per-stage times are
   affine in the chunk size (every op's bytes is a fixed multiple of m), so
   the sweep costs two schedule builds total, mirroring ``affine_time``.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.simulator import PipelinedCost, pipeline_stages, validate


# ----------------------------------------------------------------------
# Tree <-> fixed-byte buckets
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSlot:
    """Where one tree leaf lives inside its group's flat vector."""

    leaf_index: int          # position in jax.tree.leaves order
    offset: int              # element offset within the group vector
    size: int                # trailing (non-batch) element count
    shape: tuple             # trailing shape (batch dims excluded)


@dataclass(frozen=True)
class BucketGroup:
    """One (dtype, sharding-key) group: contiguous leaves, fixed-size split."""

    key: tuple
    slots: tuple
    total_elems: int
    bucket_elems: int

    @property
    def n_buckets(self) -> int:
        return max(1, math.ceil(self.total_elems / self.bucket_elems))


@dataclass(frozen=True)
class BucketLayout:
    """Round-trippable description of a bucketed tree.

    ``pack_buckets`` produces ``n_buckets`` arrays of
    ``[*batch_shape, bucket_elems]`` (a group's last bucket may be short);
    ``unpack_buckets`` restores the original tree (optionally with a
    different batch shape -- the pod-combined output has none).
    """

    treedef: object
    groups: tuple
    batch_ndim: int
    batch_shape: tuple

    @property
    def n_buckets(self) -> int:
        return sum(g.n_buckets for g in self.groups)

    def describe(self) -> str:
        return (
            f"{self.n_buckets} buckets over {len(self.groups)} "
            f"(dtype, sharding) groups"
        )


def _leaf_key(leaf, spec) -> tuple:
    return (str(leaf.dtype), str(spec) if spec is not None else "")


def plan_buckets(
    tree,
    bucket_bytes: int,
    *,
    specs=None,
    batch_ndim: int = 0,
    reverse: bool = False,
) -> BucketLayout:
    """Plan fixed-byte buckets for ``tree``.

    specs:       optional pytree of per-leaf sharding specs (same structure);
                 leaves with different specs never share a bucket.
    batch_ndim:  leading dims excluded from bucketing (1 for the vmap-mode
                 [n_pods, ...] gradient stacks); must agree across leaves.
    reverse:     lay leaves out in REVERSE ``jax.tree.leaves`` order.  The
                 parameter tree is layer-ordered and backward produces the
                 last layers' gradients first, so reverse-layer buckets
                 become ready earliest-last-layer-first -- the layout the
                 compute-overlapped sync wants (``simulate_overlapped``).
                 ``unpack_buckets`` restores the original tree either way.
    """
    import jax

    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be positive, got {bucket_bytes}")
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot bucket an empty tree")
    if specs is not None:
        from jax.sharding import PartitionSpec

        spec_leaves = jax.tree.flatten(
            specs,
            is_leaf=lambda x: x is None or isinstance(x, PartitionSpec),
        )[0]
    else:
        spec_leaves = [None] * len(leaves)
    if len(spec_leaves) != len(leaves):
        raise ValueError(
            f"specs tree has {len(spec_leaves)} leaves, grads {len(leaves)}"
        )
    batch_shape = tuple(leaves[0].shape[:batch_ndim])
    indexed = list(enumerate(zip(leaves, spec_leaves)))
    if reverse:
        indexed = indexed[::-1]
    groups: dict[tuple, list] = {}
    order: list[tuple] = []
    for i, (leaf, spec) in indexed:
        if tuple(leaf.shape[:batch_ndim]) != batch_shape:
            raise ValueError(
                f"leaf {i} batch shape {leaf.shape[:batch_ndim]} != "
                f"{batch_shape}"
            )
        key = _leaf_key(leaf, spec)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append((i, leaf))
    out = []
    for key in order:
        slots, offset = [], 0
        itemsize = groups[key][0][1].dtype.itemsize
        for i, leaf in groups[key]:
            trailing = tuple(leaf.shape[batch_ndim:])
            size = int(math.prod(trailing)) if trailing else 1
            slots.append(LeafSlot(i, offset, size, trailing))
            offset += size
        bucket_elems = max(1, int(bucket_bytes) // itemsize)
        out.append(
            BucketGroup(
                key=key, slots=tuple(slots), total_elems=offset,
                bucket_elems=bucket_elems,
            )
        )
    return BucketLayout(
        treedef=treedef, groups=tuple(out), batch_ndim=batch_ndim,
        batch_shape=batch_shape,
    )


def pack_buckets(layout: BucketLayout, tree) -> list:
    """Tree -> list of contiguous bucket arrays ``[*batch, <=bucket_elems]``."""
    import jax
    import jax.numpy as jnp

    leaves = jax.tree.leaves(tree)
    buckets = []
    for g in layout.groups:
        flat = jnp.concatenate(
            [
                leaves[s.leaf_index].reshape(*layout.batch_shape, -1)
                for s in g.slots
            ],
            axis=-1,
        )
        for b in range(g.n_buckets):
            lo = b * g.bucket_elems
            hi = min(lo + g.bucket_elems, g.total_elems)
            buckets.append(flat[..., lo:hi])
    return buckets


def unpack_buckets(layout: BucketLayout, buckets, *, batch_shape=None):
    """Inverse of ``pack_buckets``.

    ``batch_shape`` overrides the layout's (pass ``()`` when the combine
    collapsed the pod dim); bucket arrays must carry that batch shape.
    """
    import jax
    import jax.numpy as jnp

    if batch_shape is None:
        batch_shape = layout.batch_shape
    batch_shape = tuple(batch_shape)
    if len(buckets) != layout.n_buckets:
        raise ValueError(
            f"got {len(buckets)} buckets, layout has {layout.n_buckets}"
        )
    leaves = [None] * sum(len(g.slots) for g in layout.groups)
    pos = 0
    for g in layout.groups:
        flat = jnp.concatenate(
            list(buckets[pos:pos + g.n_buckets]), axis=-1
        )
        pos += g.n_buckets
        for s in g.slots:
            piece = flat[..., s.offset:s.offset + s.size]
            leaves[s.leaf_index] = piece.reshape(*batch_shape, *s.shape)
    return jax.tree.unflatten(layout.treedef, leaves)


# ----------------------------------------------------------------------
# Cost-model-chosen bucket size
# ----------------------------------------------------------------------

# Bucket sizes below this stop amortizing even a calibrated DCN alpha and
# explode the bucket count; sizes are swept in powers of two above it.
MIN_BUCKET_BYTES = 1 << 16
MAX_CHUNKS = 256


@dataclass(frozen=True)
class BucketedChoice:
    """Outcome of a pipelined bucket-size sweep for one schedule family."""

    n_chunks: int
    bucket_bytes: float
    t_monolithic: float       # n_chunks=1: the unbucketed schedule
    t_pipelined: float
    stages_monolithic: tuple

    @property
    def speedup(self) -> float:
        return self.t_monolithic / self.t_pipelined if self.t_pipelined else 1.0


def stage_affine(build, m1: float = 1024.0, m2: float = 2048.0) -> list:
    """Per-pipeline-stage (kind, A, B) with stage time t(m) = A + B*m.

    Stage structure (which rounds exist, which tier each uses) is
    independent of the message size; only durations scale, and they scale
    affinely (every op's bytes is a fixed multiple of m).  Two builds pin
    every stage's curve, after which pipelined times for arbitrary chunk
    sizes are O(n_stages) -- the ``affine_time`` idiom extended per stage.
    """
    s1, s2 = build(m1), build(m2)
    validate(s1)
    st1, st2 = pipeline_stages(s1), pipeline_stages(s2)
    if [k for k, _ in st1] != [k for k, _ in st2]:
        raise ValueError("stage structure changed with message size")
    out = []
    for (kind, t1), (_, t2) in zip(st1, st2):
        B = (t2 - t1) / (m2 - m1)
        out.append((kind, t1 - B * m1, B))
    return out


def pipelined_time_affine(stages, m: float, n_chunks: int) -> float:
    """Pipelined total from per-stage affine coefficients (exact, O(S))."""
    chunk_m = m / n_chunks
    ts = [A + B * chunk_m for _, A, B in stages]
    return sum(ts) + (n_chunks - 1) * max(ts, default=0.0)


def chunk_counts(
    nbytes: float,
    min_bucket_bytes: int = MIN_BUCKET_BYTES,
    max_chunks: int = MAX_CHUNKS,
) -> list:
    """The candidate chunk counts every sweep shares: 1, 2, 4, ... while
    the chunk stays >= ``min_bucket_bytes`` and the count <= ``max_chunks``
    (the latency-amortization floor and the runaway cap)."""
    ns, n = [1], 2
    while n <= max_chunks and nbytes / n >= min_bucket_bytes:
        ns.append(n)
        n *= 2
    return ns


def choose_n_chunks(
    build,
    nbytes: float,
    *,
    min_bucket_bytes: int = MIN_BUCKET_BYTES,
    max_chunks: int = MAX_CHUNKS,
    stages=None,
) -> BucketedChoice:
    """Sweep chunk counts under the pipelined cost view; return the best.

    ``build``: message size -> Schedule (e.g. a registry spec's
    ``build_schedule`` partial).  The sweep covers ``chunk_counts`` -- the
    alpha/beta of ``build``'s topology decide the winner.  ``stages``
    optionally supplies precomputed ``stage_affine`` curves (planners
    pricing several views of one family reuse them).
    """
    if stages is None:
        stages = stage_affine(build)
    t_mono = pipelined_time_affine(stages, nbytes, 1)
    best_n, best_t = 1, t_mono
    for n in chunk_counts(nbytes, min_bucket_bytes, max_chunks)[1:]:
        t = pipelined_time_affine(stages, nbytes, n)
        if t < best_t:
            best_n, best_t = n, t
    return BucketedChoice(
        n_chunks=best_n,
        bucket_bytes=math.ceil(nbytes / best_n),
        t_monolithic=t_mono,
        t_pipelined=best_t,
        stages_monolithic=tuple(
            (k, A + B * nbytes) for k, A, B in stages
        ),
    )


def simulate_choice(build, nbytes: float, n_chunks: int) -> PipelinedCost:
    """Exact (non-affine) pipelined cost for one chunk count -- the slow
    twin of ``pipelined_time_affine`` used by tests to cross-check it."""
    from repro.core.simulator import simulate_pipelined

    return simulate_pipelined(build, nbytes, n_chunks, check=False)


# ----------------------------------------------------------------------
# Compute-overlapped bucket-size selection
# ----------------------------------------------------------------------

def overlapped_time_affine(
    stages, m: float, n_chunks: int, compute_time: float,
    dispatch_cost: float = 0.0,
) -> float:
    """``simulate_overlapped`` total from per-stage affine coefficients.

    Exact O(S) twin of the simulator's closed form: buckets released
    uniformly over the ``compute_time`` backward shadow, comm pipelined
    behind the releases; only the comm escaping the shadow is charged.
    ``dispatch_cost`` stretches the shadow by one issue overhead per bucket
    (see ``simulate_overlapped``).  ``compute_time = 0, dispatch_cost = 0``
    reduces to ``pipelined_time_affine`` exactly.
    """
    chunk_m = m / n_chunks
    ts = [A + B * chunk_m for _, A, B in stages]
    t_chunk = sum(ts)
    b = max(ts, default=0.0)
    shadow = compute_time + n_chunks * dispatch_cost
    return t_chunk + max(
        shadow, shadow / n_chunks + (n_chunks - 1) * b
    )


@dataclass(frozen=True)
class OverlapChoice:
    """Outcome of an overlap-aware chunk-count sweep for one sync family."""

    n_chunks: int
    bucket_bytes: float
    compute_time: float
    t_overlapped: float       # compute + exposed comm at the chosen chunking
    t_serial: float           # compute + best post-backward pipelined sync
    stages: tuple
    dispatch_cost: float = 0.0

    @property
    def t_exposed(self) -> float:
        return self.t_overlapped - self.compute_time

    @property
    def speedup(self) -> float:
        return self.t_serial / self.t_overlapped if self.t_overlapped else 1.0


def choose_overlap(
    build,
    nbytes: float,
    compute_time: float,
    *,
    min_bucket_bytes: int = MIN_BUCKET_BYTES,
    max_chunks: int = MAX_CHUNKS,
    n_chunks: int | None = None,
    stages=None,
    dispatch_cost: float = 0.0,
) -> OverlapChoice:
    """Sweep chunk counts under the compute-overlapped view; return the best.

    Like ``choose_n_chunks`` but pricing ``overlapped_time_affine``: deeper
    chunking releases comm earlier into the backward shadow but pays more
    per-message alphas (and, with ``dispatch_cost > 0``, one issue overhead
    per bucket on the compute path); the fitted stage curves decide.
    ``n_chunks`` pins the chunk count instead of sweeping.  ``t_serial``
    reports the best UNoverlapped plan (compute, then the
    ``choose_n_chunks`` pipelined sync, no dispatch charge) so callers can
    compare overlap on vs off at their respective optima.
    """
    if stages is None:
        stages = stage_affine(build)
    serial = choose_n_chunks(
        build, nbytes,
        min_bucket_bytes=min_bucket_bytes, max_chunks=max_chunks,
        stages=stages,
    )
    t_serial = compute_time + serial.t_pipelined
    if n_chunks is not None:
        best_n = max(1, int(n_chunks))
        best_t = overlapped_time_affine(
            stages, nbytes, best_n, compute_time, dispatch_cost
        )
    else:
        best_n, best_t = 1, overlapped_time_affine(
            stages, nbytes, 1, compute_time, dispatch_cost
        )
        for n in chunk_counts(nbytes, min_bucket_bytes, max_chunks)[1:]:
            t = overlapped_time_affine(
                stages, nbytes, n, compute_time, dispatch_cost
            )
            if t < best_t:
                best_n, best_t = n, t
    return OverlapChoice(
        n_chunks=best_n,
        bucket_bytes=math.ceil(nbytes / best_n),
        compute_time=compute_time,
        t_overlapped=best_t,
        t_serial=t_serial,
        stages=tuple((k, A + B * nbytes / best_n) for k, A, B in stages),
        dispatch_cost=dispatch_cost,
    )
