"""Runtime health for planned communication: watchdog, retry, replan.

The planner prices a step before it runs; this module watches what the
step *actually* took and reacts when reality drifts from the model --
the robustness counterpart of calibration.  Three pieces, all plain
Python (no jax) so the simulator and the live trainer share them:

* ``StepWatchdog`` -- an EWMA drift detector seeded from the *modelled*
  step time.  ``observe(t)`` classifies each step as ``ok`` (within the
  drift band), ``slow`` (over the band: the fitted parameters have
  drifted and a refit/re-plan is warranted), or ``lost`` (over the
  timeout threshold: a participant is presumed dead -- the elastic
  recovery path, not a re-plan, is the answer).  ``timeout_s`` is the
  detection latency a fault scenario charges for a node kill.

* ``RetryPolicy`` / ``retry_with_backoff`` -- bounded exponential backoff
  around executable collectives.  Transient failures (a dropped
  connection mid all-reduce) retry up to ``max_attempts`` with
  deterministic delays; anything still failing propagates.  The
  simulator prices the same delays via ``RetryPolicy.delay`` without
  sleeping.

* ``ReplanMonitor`` -- glues a watchdog to a ``replan`` callback with
  hysteresis: ``patience`` consecutive slow steps trigger one replan,
  then observation restarts against the new expectation.  The trainer
  and the serving loop both drive their degraded-topology re-planning
  through this object.
"""

from __future__ import annotations

import time as _time
from dataclasses import dataclass, field


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff: attempt k waits base * backoff**k."""

    max_attempts: int = 4
    base_delay_s: float = 0.05
    backoff: float = 2.0
    max_delay_s: float = 2.0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s < 0 or self.max_delay_s < 0:
            raise ValueError("delays must be >= 0")
        if self.backoff < 1.0:
            raise ValueError("backoff must be >= 1")

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (0 = first retry)."""
        return min(self.base_delay_s * self.backoff ** attempt,
                   self.max_delay_s)

    def total_delay(self, n_retries: int) -> float:
        """Summed backoff across ``n_retries`` consecutive retries --
        what the simulator charges a step that hit transient drops."""
        return sum(self.delay(k) for k in range(n_retries))


def retry_with_backoff(fn, policy: RetryPolicy = RetryPolicy(), *,
                       retriable=(RuntimeError, OSError),
                       sleep=_time.sleep, on_retry=None):
    """Run ``fn()``; on a retriable exception, back off and retry.

    Raises the last exception after ``policy.max_attempts`` total
    attempts.  ``on_retry(attempt, exc)`` is called before each backoff
    (logging / metrics hook); ``sleep`` is injectable so tests and the
    simulator stay wall-clock-free.
    """
    last = None
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except retriable as exc:
            last = exc
            if attempt + 1 >= policy.max_attempts:
                break
            if on_retry is not None:
                on_retry(attempt, exc)
            sleep(policy.delay(attempt))
    raise last


@dataclass
class StepWatchdog:
    """EWMA drift detector + node-loss timeout over per-step times.

    ``expected_s`` seeds the EWMA with the *modelled* step time, so the
    very first observation already has a meaningful reference; the EWMA
    then tracks slow drift (thermal, congestion) without tripping on it,
    while the ``drift_band`` catches genuine regime change.
    """

    expected_s: float
    alpha: float = 0.2            # EWMA smoothing weight for new samples
    drift_band: float = 1.5       # slow when t > band * max(ewma, expected)
    timeout_factor: float = 5.0   # lost when t > factor * max(ewma, expected)
    ewma_s: float = field(init=False)
    n_observed: int = field(init=False, default=0)
    n_slow: int = field(init=False, default=0)

    def __post_init__(self) -> None:
        if self.expected_s <= 0:
            raise ValueError(f"expected_s must be > 0, got {self.expected_s}")
        if not 0 < self.alpha <= 1:
            raise ValueError("alpha must be in (0, 1]")
        if not 1 < self.drift_band < self.timeout_factor:
            raise ValueError(
                "need 1 < drift_band < timeout_factor, got "
                f"{self.drift_band} / {self.timeout_factor}"
            )
        self.ewma_s = float(self.expected_s)

    @property
    def reference_s(self) -> float:
        """What a healthy step should take right now."""
        return max(self.ewma_s, self.expected_s)

    @property
    def slow_threshold_s(self) -> float:
        return self.drift_band * self.reference_s

    @property
    def timeout_s(self) -> float:
        """Give-up threshold: past this, a participant is presumed lost.
        This is the detection latency charged for a node kill."""
        return self.timeout_factor * self.reference_s

    def observe(self, t_step: float) -> str:
        """Classify one step time: 'ok' | 'slow' | 'lost'.

        Only non-pathological samples feed the EWMA -- a timeout must not
        drag the reference up and mask the next fault.
        """
        self.n_observed += 1
        if t_step > self.timeout_s:
            return "lost"
        verdict = "ok"
        if t_step > self.slow_threshold_s:
            self.n_slow += 1
            verdict = "slow"
        self.ewma_s += self.alpha * (t_step - self.ewma_s)
        return verdict

    def rebase(self, expected_s: float) -> None:
        """Reset against a new modelled step time (after a re-plan)."""
        if expected_s <= 0:
            raise ValueError(f"expected_s must be > 0, got {expected_s}")
        self.expected_s = float(expected_s)
        self.ewma_s = float(expected_s)
        self.n_slow = 0


class ReplanMonitor:
    """Watchdog + hysteresis + a replan callback.

    ``observe(t)`` forwards to the watchdog; after ``patience``
    *consecutive* slow steps it calls ``replan()`` once and rebases the
    watchdog on the value ``replan`` returns (the newly modelled step
    time).  'lost' verdicts pass straight through -- node loss needs the
    recovery path, not a refit.
    """

    def __init__(self, watchdog: StepWatchdog, replan, *,
                 patience: int = 3) -> None:
        if patience < 1:
            raise ValueError("patience must be >= 1")
        self.watchdog = watchdog
        self.replan = replan
        self.patience = patience
        self.slow_streak = 0
        self.n_replans = 0

    def observe(self, t_step: float) -> str:
        verdict = self.watchdog.observe(t_step)
        if verdict == "slow":
            self.slow_streak += 1
            if self.slow_streak >= self.patience:
                new_expected = self.replan()
                self.n_replans += 1
                self.slow_streak = 0
                if new_expected is not None:
                    self.watchdog.rebase(float(new_expected))
                verdict = "replanned"
        elif verdict == "ok":
            self.slow_streak = 0
        return verdict
