"""Training step: loss, grad accumulation, mixed precision, pod-tier sync.

Three pod modes (the paper-vs-baseline axis of this framework):

  * ``none``   -- single-pod mesh, plain GSPMD jit.
  * ``gspmd``  -- multi-pod mesh, hierarchy-OBLIVIOUS: batch sharded over
                  ('pod','data'), one global loss; the partitioner emits one
                  flat all-reduce over all 512 devices inside backward.
                  The paper's strawman.
  * ``manual`` -- multi-pod mesh, the paper's schedule.  The pod dim is made
                  explicit by vmapping the per-pod loss over a leading
                  [n_pods, ...] batch dim sharded over 'pod': gradients come
                  out PER-POD (sharded over 'pod'), and the DCN-tier exchange
                  is then scheduled by this code, not the partitioner --
                  full-precision mean (parallel-egress psum of FSDP shards)
                  or int8-compressed (q8) where only int8 payloads + f32
                  block scales cross the pod seam.

The pod-tier wire formats ('flat', 'q8', and the reduce-scatter-based 'rs'
/ 'rs_q8') and their planner live in ``repro.comm``: the combiner here is
``comm.pod_combine`` (optionally bucketed into fixed-byte buckets so the
local tier of bucket k+1 overlaps the DCN exchange of bucket k), and
``pod_sync="auto"`` lets the pipelined cost model pick format AND bucket
size per gradient (``comm.plan_pod_sync``) -- the registry guarantees the
pick is runnable.

(Implementation note: an earlier version used shard_map(axis_names={'pod'})
for the manual tier; XLA 0.8's SPMD partitioner check-fails on gather /
reshard ops under partial-manual subgroups, so the pod dim is expressed via
vmap + sharding constraints instead -- same collectives in the compiled HLO,
no crashing path.  The shard_map collectives in repro.comm remain the
reference implementations and are exercised by multi-device tests.)
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import comm
from repro.core.topology import V5E_CHIPS_PER_POD
from repro.models import lm
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import rules


@dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    remat: str = "nothing"       # see lm.REMAT_POLICIES
    aux_weight: float = 0.01
    pod_mode: str = "none"       # none | gspmd | manual
    pod_sync: str = "flat"       # flat | q8 | rs | rs_q8 | auto  (manual
    #                              mode only; auto = let repro.comm's
    #                              planner pick format AND bucket size)
    # pod-tier bucket size in bytes: 0 = monolithic sync; with
    # pod_sync="auto" the planner's pipelined cost model chooses it
    # (an explicit value here always wins)
    bucket_bytes: int = 0
    use_kernel: bool = True
    n_pods: int = 1
    # bf16 halves the gradient-accumulator HBM for the 314B single-pod cell
    accum_dtype: str = "float32"
    # path to a comm.calibrate JSON; pod_sync="auto" then plans against the
    # empirically fitted topology instead of the preset v5e constants
    # ("" = also honor $REPRO_CALIBRATION, else presets)
    calibration: str = ""
    # named topology preset the pod-sync planner models the cluster with
    # (repro.core.topology.TOPOLOGY_PRESETS): "v5e" = two-tier collapse,
    # "v5e_3tier" = the full ICI / host-PCIe / DCN hierarchy
    topology: str = "v5e"
    # compute/comm overlap for the pod-tier sync (manual mode, accum_steps
    # > 1): "off" = serial backward -> sync -> update; "auto" = let the
    # overlap-aware cost model decide (per-microbatch partial-mean syncs
    # riding the next microbatch's backward, reverse-layer buckets, and a
    # per-bucket optimizer update); an int forces that overlap depth
    # (buckets per sync)
    overlap: str | int = "off"
    # measured (or estimated -- see estimate_compute_time) seconds of one
    # step's forward+backward compute; sizes the backward shadow the
    # overlap planner hides comm under.  0 with overlap="auto" makes the
    # model see no shadow and keep the serial plan.
    compute_time: float = 0.0

    model_in_batch: bool = False   # fold_model policy: batch over model too

    @property
    def batch_axes(self):
        base = ("data", "model") if self.model_in_batch else ("data",)
        return (("pod",) + base) if self.pod_mode == "gspmd" else base


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL; logits f32 [B,S,V], labels int [B,S].

    The gold logit is extracted by a one-hot contraction, not
    take_along_axis: gathering along a tensor-parallel vocab dim would force
    GSPMD to all-gather the full logits (V-replication); the contraction
    stays sharded and lowers to a local reduce + small all-reduce.
    """
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig):
    def loss_fn(params, batch):
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["enc_embeds"] = batch["enc_embeds"]
        if cfg.family == "vlm" and "embeds" in batch:
            logits, aux = lm.forward(
                params, cfg, embeds=batch["embeds"],
                positions=batch.get("positions"),
                remat=tcfg.remat, use_kernel=tcfg.use_kernel,
                batch_axes=tcfg.batch_axes, **kwargs,
            )
        else:
            logits, aux = lm.forward(
                params, cfg, tokens=batch["tokens"],
                remat=tcfg.remat, use_kernel=tcfg.use_kernel,
                batch_axes=tcfg.batch_axes, **kwargs,
            )
        ce = cross_entropy(logits, batch["labels"])
        return ce + tcfg.aux_weight * aux, (ce, aux)

    return loss_fn


def _accum_grads(loss_fn, params, batch, accum: int,
                 accum_dtype: str = "float32"):
    """Gradient accumulation over microbatches via lax.scan (one HLO body)."""
    if accum == 1:
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, ce, aux, grads

    def micro(x, axis=0):
        return x.reshape(
            *x.shape[:axis], accum, x.shape[axis] // accum, *x.shape[axis + 1:]
        ).swapaxes(0, axis) if axis else x.reshape(
            accum, x.shape[0] // accum, *x.shape[1:]
        )

    mb = {
        k: micro(v, axis=1 if k == "positions" else 0) for k, v in batch.items()
    }

    adt = jnp.dtype(accum_dtype)

    def body(carry, b):
        acc, closs = carry
        (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, b
        )
        acc = jax.tree.map(lambda a, g: a + g.astype(adt), acc, grads)
        return (acc, closs + loss), (ce, aux)

    zero = jax.tree.map(
        lambda p: jnp.zeros(p.shape, adt), params
    )
    (gsum, losssum), (ces, auxs) = lax.scan(body, (zero, 0.0), mb)
    inv = 1.0 / accum
    grads = jax.tree.map(lambda g: g * inv, gsum)
    return losssum * inv, jnp.mean(ces), jnp.mean(auxs), grads


# ----------------------------------------------------------------------
# Pod-tier gradient combine (manual mode; wire formats in repro.comm)
# ----------------------------------------------------------------------

def _constrain_tree(tree, spec_tree):
    # Same narrow fallback as repro.comm.grad_sync._pin: only the
    # "no/incompatible ambient mesh" errors degrade to identity.
    def c(x, sp):
        try:
            return jax.lax.with_sharding_constraint(x, sp)
        except (ValueError, RuntimeError):
            return x
    return jax.tree.map(c, tree, spec_tree, is_leaf=lambda x: x is None)


# Re-exported for compatibility; implementations live in repro.comm.grad_sync.
pod_combine_flat = comm.pod_combine_flat
pod_combine_q8 = comm.pod_combine_q8


def parse_overlap(value: "str | int") -> "str | int":
    """Normalize a TrainConfig / CLI overlap knob: 'off' | 'auto' | int."""
    if isinstance(value, int):
        return value
    if value in ("off", "auto"):
        return value
    try:
        return int(value)
    except (TypeError, ValueError):
        raise ValueError(
            f"overlap must be 'off', 'auto' or an int, got {value!r}"
        ) from None


def estimate_compute_time(
    cfg: ModelConfig,
    tokens_per_pod: float,
    chips_per_pod: int | None = None,
    mfu: float = 0.4,
) -> float:
    """Roofline estimate of one step's forward+backward seconds per pod.

    6 * params * tokens FLOPs (fwd + bwd) over the pod's aggregate peak at
    an assumed ``mfu``.  A stand-in for a measured step time: pass the real
    number through ``TrainConfig.compute_time`` when you have one (e.g.
    from a serial warm-up step) -- the overlap planner only uses it to size
    the backward shadow, so ballpark accuracy moves the bucket count by at
    most a power of two.
    """
    from repro.core.topology import V5E_PEAK_FLOPS

    if chips_per_pod is None:
        chips_per_pod = V5E_CHIPS_PER_POD
    return (
        6.0 * cfg.param_count() * tokens_per_pod
        / (V5E_PEAK_FLOPS * chips_per_pod * mfu)
    )


def plan_pod_sync(
    cfg: ModelConfig,
    tcfg: "TrainConfig",
    n_pods: int,
    chips_per_pod: int | None = None,
    dispatch_cost: float | None = None,
) -> "comm.PodSyncDecision":
    """Resolve the pod-tier sync decision (format + bucket size + overlap).

    Plans a DCN-tier gradient sync of this model's per-chip FSDP gradient
    shard (f32 bytes / chips in one pod -- pass ``chips_per_pod`` from the
    actual mesh; defaults to the production v5e pod size).  ``pod_sync=
    'auto'`` lets the pipelined cost model pick the wire format AND the
    bucket count (opting into the lossy q8 paths when compression wins);
    an explicit format (and ``bucket_bytes``) pins those choices.  With
    ``tcfg.overlap`` enabled the planner additionally weighs the
    compute-overlapped step (per-microbatch partial-mean syncs hidden
    under backward; ``tcfg.compute_time`` sizes the shadow) against the
    serial one -- also for a pinned wire format.  ``dispatch_cost``
    overrides the per-issue overhead (None = resolve from calibration /
    the committed BENCH_step fixture; benchmarks pass 0.0 to price the
    dispatch-free model they fit against).
    """
    overlap = parse_overlap(tcfg.overlap)
    manual = n_pods > 1 and tcfg.pod_mode == "manual"
    if chips_per_pod is None:
        chips_per_pod = V5E_CHIPS_PER_POD
    grad_bytes = cfg.param_count() * 4.0 / chips_per_pod
    overlap_wanted = manual and tcfg.accum_steps > 1 and (
        overlap == "auto" or (isinstance(overlap, int) and overlap > 0)
    )
    if tcfg.pod_sync != "auto":
        if tcfg.pod_sync not in comm.POD_SYNC_FORMATS:
            raise ValueError(
                f"unknown pod_sync {tcfg.pod_sync!r}; expected one of "
                f"{comm.POD_SYNC_FORMATS + ('auto',)}"
            )
        if overlap_wanted:
            # pinned wire format, but overlap (and its bucket count) still
            # priced by the cost model
            return comm.plan_pod_sync(
                n_pods, grad_bytes,
                calibration=tcfg.calibration or None,
                topology=tcfg.topology,
                bucket_bytes=tcfg.bucket_bytes or None,
                compute_time=tcfg.compute_time,
                accum_steps=tcfg.accum_steps,
                overlap=overlap,
                formats=[tcfg.pod_sync],
                dispatch_cost=dispatch_cost,
            )
        return comm.PodSyncDecision(
            fmt=tcfg.pod_sync,
            bucket_bytes=tcfg.bucket_bytes,
            n_chunks=1,
            t_modelled=0.0, t_monolithic=0.0,
            lossy=tcfg.pod_sync in comm.LOSSY_POD_SYNC_FORMATS,
        )
    if not manual:
        return comm.PodSyncDecision("flat", 0, 1, 0.0, 0.0, False)
    # An explicit bucket_bytes pins the chunking: the planner then ranks
    # the wire formats AT that bucket size instead of sweeping it.
    return comm.plan_pod_sync(
        n_pods, grad_bytes, lossy_ok=True,
        calibration=tcfg.calibration or None,
        topology=tcfg.topology,
        bucket_bytes=tcfg.bucket_bytes or None,
        compute_time=tcfg.compute_time,
        accum_steps=tcfg.accum_steps,
        overlap=overlap if overlap_wanted else "off",
        dispatch_cost=dispatch_cost,
    )


def resolve_pod_sync(
    cfg: ModelConfig,
    tcfg: "TrainConfig",
    n_pods: int,
    chips_per_pod: int | None = None,
) -> str:
    """Back-compat wrapper: the chosen wire format only (see plan_pod_sync)."""
    return plan_pod_sync(cfg, tcfg, n_pods, chips_per_pod).fmt


def _overlapped_manual_step(
    loss_fn, params, opt_state, bp, axes, tcfg: TrainConfig, ocfg,
    n_pods: int, gspecs, fmt: str, bucket_bytes: int,
):
    """Manual-mode step with compute/comm overlap (``sync.overlap > 0``).

    Microbatch k's bucketed pod combine is issued while microbatch k+1's
    backward runs: the lax.scan carries the PREVIOUS microbatch's per-pod
    grads, so within one iteration the combine (of g_{k-1}) and the
    backward (of microbatch k) are dataflow-independent and the compiler's
    latency-hiding scheduler can run them concurrently.  The last
    microbatch is peeled out of the scan so its backward overlaps the
    second-to-last sync AND its own sync's reverse-layer buckets can chase
    the backward's per-layer gradient production.  Partial means accumulate
    per bucket in ``accum_dtype``; the optimizer update is applied from the
    buckets (``adamw.apply_updates_bucketed``) -- no full-tree barrier, the
    only cross-bucket dependency is the clip scalar.
    """
    K = tcfg.accum_steps
    adt = jnp.dtype(tcfg.accum_dtype)
    combiner = comm.bucket_combiner(fmt)

    def msplit(v, pod_axis):
        b_ax = pod_axis + 1
        v = v.reshape(
            *v.shape[:b_ax], K, v.shape[b_ax] // K, *v.shape[b_ax + 1:]
        )
        return jnp.moveaxis(v, b_ax, 0)

    mbp = {
        k: msplit(v, 1 if k == "positions" else 0) for k, v in bp.items()
    }

    def one_micro(b):
        def pp(bb):
            (loss, (ce, aux)), g = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, bb)
            return loss, ce, aux, g

        l, c, a, g = jax.vmap(pp, in_axes=(axes,))(b)
        return l, c, a, _constrain_tree(g, gspecs)

    l0, c0, a0, g0 = one_micro(jax.tree.map(lambda v: v[0], mbp))
    # reverse-layer-order buckets: backward produces the LAST layers'
    # grads first, so bucket 0 is ready earliest (simulate_overlapped's
    # release order)
    layout = comm.plan_buckets(
        g0, bucket_bytes or (1 << 62), specs=gspecs, batch_ndim=1,
        reverse=True,
    )

    def combine(g):
        return tuple(
            combiner(b, n_pods).astype(adt)
            for b in comm.pack_buckets(layout, g)
        )

    zero = []
    for g in layout.groups:
        for b in range(g.n_buckets):
            n = (
                g.bucket_elems
                if b < g.n_buckets - 1
                else g.total_elems - (g.n_buckets - 1) * g.bucket_elems
            )
            zero.append(jnp.zeros((n,), adt))
    zero = tuple(zero)
    carry = (zero, g0, l0, c0, a0)
    if K > 2:
        rest = jax.tree.map(lambda v: v[1:K - 1], mbp)

        def body(c_, b):
            acc, gprev, ls, cs, as_ = c_
            done = combine(gprev)          # sync of microbatch k-1 ...
            l, c, a, g = one_micro(b)      # ... overlaps backward of k
            acc = tuple(x + y for x, y in zip(acc, done))
            return (acc, g, ls + l, cs + c, as_ + a), None

        carry, _ = lax.scan(body, carry, rest)
    # final microbatch, peeled: its backward overlaps the previous sync,
    # and its own sync's buckets release as backward produces them
    acc, gprev, ls, cs, as_ = carry
    done = combine(gprev)
    l, c, a, glast = one_micro(jax.tree.map(lambda v: v[K - 1], mbp))
    acc = tuple(x + y for x, y in zip(acc, done))
    acc = tuple(x + y for x, y in zip(acc, combine(glast)))
    inv = 1.0 / K
    gbuckets = [x * inv for x in acc]
    new_params, new_opt, metrics = adamw.apply_updates_bucketed(
        params, gbuckets, layout, opt_state, ocfg
    )
    metrics = dict(
        metrics,
        loss=jnp.mean(ls + l) * inv,
        ce=jnp.mean(cs + c) * inv,
        aux=jnp.mean(as_ + a) * inv,
    )
    return new_params, new_opt, metrics


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    ocfg: adamw.AdamWConfig,
    mesh,
    pol: rules.ShardingPolicy,
):
    """Returns (train_step, batch_specs).

    ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``.
    """
    loss_fn = make_loss_fn(cfg, tcfg)
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    sync = plan_pod_sync(
        cfg, tcfg, n_pods, chips_per_pod=mesh.devices.size // max(n_pods, 1)
    )
    pod_sync, bucket_bytes = sync.fmt, sync.bucket_bytes
    overlapped = (
        sync.overlap > 0
        and tcfg.pod_mode == "manual"
        and n_pods > 1
        and tcfg.accum_steps > 1
    )

    def step_body(params, opt_state, batch):
        if tcfg.pod_mode == "manual" and n_pods > 1:
            bp = {
                k: (
                    v.reshape(v.shape[0], n_pods, v.shape[1] // n_pods, *v.shape[2:])
                    if k == "positions"
                    else v.reshape(n_pods, v.shape[0] // n_pods, *v.shape[1:])
                )
                for k, v in batch.items()
            }
            axes = {k: (1 if k == "positions" else 0) for k in bp}
            # pin per-pod grads to P('pod', <param spec>)
            pspecs = rules.param_specs(cfg, params, pol)
            gspecs = jax.tree.map(
                lambda sp: P("pod", *sp), pspecs,
                is_leaf=lambda x: isinstance(x, P),
            )
            if overlapped:
                return _overlapped_manual_step(
                    loss_fn, params, opt_state, bp, axes, tcfg, ocfg,
                    n_pods, gspecs, pod_sync, bucket_bytes,
                )

            def per_pod(b):
                return _accum_grads(loss_fn, params, b, tcfg.accum_steps,
                                    tcfg.accum_dtype)

            losses, ces, auxs, gpod = jax.vmap(per_pod, in_axes=(axes,))(bp)
            gpod = _constrain_tree(gpod, gspecs)
            grads = comm.pod_combine(
                gpod, n_pods, gspecs, fmt=pod_sync,
                bucket_bytes=bucket_bytes,
            )
            loss, ce, aux = jnp.mean(losses), jnp.mean(ces), jnp.mean(auxs)
        else:
            loss, ce, aux, grads = _accum_grads(
                loss_fn, params, batch, tcfg.accum_steps, tcfg.accum_dtype
            )
        new_params, new_opt, metrics = adamw.apply_updates(
            params, grads, opt_state, ocfg
        )
        metrics = dict(metrics, loss=loss, ce=ce, aux=aux)
        return new_params, new_opt, metrics

    pod_axis = "pod" if (tcfg.pod_mode in ("gspmd", "manual") and n_pods > 1) else None
    bspecs = rules.batch_specs(cfg, pol, pod_axis=pod_axis)
    return step_body, bspecs
