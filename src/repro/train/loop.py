"""Fault-tolerant training driver.

Wraps the jitted train step with the operational machinery a 1000-node job
needs:

  * checkpoint-restart: resume from the newest complete checkpoint
    (``Checkpointer`` commits atomically, validates CRCs);
  * periodic async snapshots (no step-time stall beyond device->host copy);
  * straggler / hang mitigation: a per-step deadline; steps exceeding it are
    logged and counted -- on real pods the runner would trigger the
    re-mesh path (here: surfaced via metrics and exercised in tests with an
    injected slow step);
  * crash injection hooks for tests (``fail_at_step``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


from repro.checkpoint.checkpointer import Checkpointer


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    step_deadline_s: float = 0.0      # 0 = disabled
    fail_at_step: int = -1            # test hook: raise mid-run


@dataclass
class LoopState:
    step: int = 0
    losses: list = field(default_factory=list)
    slow_steps: list = field(default_factory=list)


def run(
    train_step,
    params,
    opt_state,
    pipeline,
    lcfg: LoopConfig,
    log=print,
) -> LoopState:
    """Run (or resume) training.  Returns the loop state."""
    ckpt = Checkpointer(lcfg.ckpt_dir, keep=lcfg.keep)
    state = LoopState()

    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), step0 = ckpt.restore((params, opt_state))
        state.step = step0
        log(f"[loop] resumed from step {step0}")

    while state.step < lcfg.total_steps:
        batch = pipeline.batch(state.step)
        t0 = time.time()
        if state.step == lcfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {state.step}")
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if lcfg.step_deadline_s and dt > lcfg.step_deadline_s:
            state.slow_steps.append((state.step, dt))
            log(f"[loop] STRAGGLER step {state.step}: {dt:.2f}s "
                f"(deadline {lcfg.step_deadline_s:.2f}s)")
        state.step += 1
        state.losses.append(loss)
        if state.step % lcfg.log_every == 0:
            log(f"[loop] step {state.step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt*1000:.0f}ms")
        if state.step % lcfg.ckpt_every == 0 or state.step == lcfg.total_steps:
            ckpt.save(state.step, (params, opt_state))
    ckpt.wait()
    state.params = params          # type: ignore[attr-defined]
    state.opt_state = opt_state    # type: ignore[attr-defined]
    return state
