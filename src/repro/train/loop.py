"""Fault-tolerant training driver.

Wraps the jitted train step with the operational machinery a 1000-node job
needs:

  * checkpoint-restart: resume from the newest complete checkpoint
    (``Checkpointer`` commits atomically, validates CRCs, and falls back
    past a corrupt snapshot);
  * periodic async snapshots (no step-time stall beyond device->host copy);
  * straggler / hang mitigation: a per-step deadline; steps exceeding it are
    logged and counted, and an optional ``comm.health.ReplanMonitor``
    watches the same timings to trigger a re-plan when drift persists;
  * elastic recovery: a step that raises ``NodeLossError`` (injected via
    ``lose_node_at_step`` or raised by a real runner's health checks)
    restores the newest checkpoint and hands control to the caller's
    ``recover`` hook, which re-meshes onto the survivors and returns a new
    step function -- training continues on the shrunk cluster, and the
    wall-clock recovery time lands in ``LoopState.recoveries``;
  * crash injection hooks for tests (``fail_at_step``).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


from repro.checkpoint.checkpointer import Checkpointer


class NodeLossError(RuntimeError):
    """A participant died mid-step: trigger the elastic recovery path."""


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep: int = 3
    log_every: int = 10
    step_deadline_s: float = 0.0      # 0 = disabled
    fail_at_step: int = -1            # test hook: raise mid-run
    lose_node_at_step: int = -1       # test hook: NodeLossError mid-run


@dataclass
class LoopState:
    step: int = 0
    losses: list = field(default_factory=list)
    slow_steps: list = field(default_factory=list)
    recoveries: list = field(default_factory=list)


def run(
    train_step,
    params,
    opt_state,
    pipeline,
    lcfg: LoopConfig,
    log=print,
    *,
    recover=None,
    monitor=None,
) -> LoopState:
    """Run (or resume) training.  Returns the loop state.

    ``recover(params, opt_state)`` is the elastic hook: called after a
    ``NodeLossError`` with the checkpoint-restored state, it must return
    ``(train_step, params, opt_state)`` re-meshed onto the surviving
    devices.  Without it, node loss propagates like any crash.
    ``monitor`` is an optional ``comm.health.ReplanMonitor`` fed every
    step's wall-clock time.
    """
    ckpt = Checkpointer(lcfg.ckpt_dir, keep=lcfg.keep)
    state = LoopState()

    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt_state), step0 = ckpt.restore((params, opt_state))
        state.step = step0
        log(f"[loop] resumed from step {step0}")

    pending_loss = lcfg.lose_node_at_step
    while state.step < lcfg.total_steps:
        batch = pipeline.batch(state.step)
        t0 = time.time()
        if state.step == lcfg.fail_at_step:
            raise RuntimeError(f"injected failure at step {state.step}")
        try:
            if state.step == pending_loss:
                raise NodeLossError(
                    f"injected node loss at step {state.step}"
                )
            params, opt_state, metrics = train_step(params, opt_state, batch)
        except NodeLossError as exc:
            if recover is None:
                raise
            pending_loss = -1  # fires once; the shrunk cluster runs on
            t_rec = time.time()
            lost_at = state.step
            log(f"[loop] NODE LOSS at step {lost_at}: {exc}")
            ckpt.wait()        # join any in-flight snapshot before scanning
            restored_from = ckpt.latest_step()
            if restored_from is not None:
                (params, opt_state), step0 = ckpt.restore(
                    (params, opt_state)
                )
                state.step = step0
                # the rewound steps' losses get recomputed after resume
                n_rewound = min(lost_at - step0, len(state.losses))
                if n_rewound > 0:
                    del state.losses[-n_rewound:]
            train_step, params, opt_state = recover(params, opt_state)
            dt_rec = time.time() - t_rec
            state.recoveries.append({
                "lost_at_step": lost_at,
                "restored_from_step": restored_from,
                "resumed_at_step": state.step,
                "recovery_time_s": dt_rec,
            })
            log(f"[loop] recovered in {dt_rec:.2f}s: resumed at step "
                f"{state.step} from ckpt {restored_from}")
            continue
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if lcfg.step_deadline_s and dt > lcfg.step_deadline_s:
            state.slow_steps.append((state.step, dt))
            log(f"[loop] STRAGGLER step {state.step}: {dt:.2f}s "
                f"(deadline {lcfg.step_deadline_s:.2f}s)")
        if monitor is not None and monitor.observe(dt) == "replanned":
            log(f"[loop] REPLAN at step {state.step}: step time drifted "
                f"to {dt * 1e3:.0f}ms")
        state.step += 1
        state.losses.append(loss)
        if state.step % lcfg.log_every == 0:
            log(f"[loop] step {state.step:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} {dt*1000:.0f}ms")
        if state.step % lcfg.ckpt_every == 0 or state.step == lcfg.total_steps:
            ckpt.save(state.step, (params, opt_state))
    ckpt.wait()
    state.params = params          # type: ignore[attr-defined]
    state.opt_state = opt_state    # type: ignore[attr-defined]
    return state
