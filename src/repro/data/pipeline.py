"""Deterministic data pipeline.

Sources:
  * ``SyntheticLM``   -- seeded zipfian token stream (default; offline box).
  * ``MemmapTokens``  -- flat uint16/uint32 token file (real corpora).

Determinism & fault tolerance: a batch is a pure function of (seed, step,
shard), so a restarted / re-sharded job replays exactly the stream it would
have seen -- no data-loader state in checkpoints beyond the step counter.
Per-host sharding: each host materializes only its slice of the global
batch (data-parallel input pipeline; on multi-host TPU this is the standard
per-host infeed).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    kind: str = "synthetic"      # synthetic | memmap
    path: str = ""
    n_shards: int = 1            # hosts
    shard_id: int = 0


class SyntheticLM:
    """Zipf-distributed tokens with a deterministic per-(step, shard) seed.

    Not i.i.d. uniform -- a zipfian marginal keeps the embedding gradient
    sparsity realistic for perf work.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.local_batch = cfg.global_batch // cfg.n_shards
        # zipf cdf over vocab
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = 1.0 / ranks ** 1.1
        self._cdf = np.cumsum(probs / probs.sum())

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, step, cfg.shard_id])
        )
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = np.searchsorted(self._cdf, u).astype(np.int32)
        toks = np.clip(toks, 0, cfg.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapTokens:
    """Flat binary token file, deterministic strided reads per (step, shard)."""

    def __init__(self, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards
        self._data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self.n_tokens = len(self._data)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.Generator(
            np.random.Philox(key=cfg.seed, counter=[0, 0, step, cfg.shard_id])
        )
        starts = rng.integers(
            0, self.n_tokens - cfg.seq_len - 1, size=self.local_batch
        )
        rows = np.stack(
            [self._data[s:s + cfg.seq_len + 1] for s in starts]
        ).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_pipeline(cfg: DataConfig):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg)
    if cfg.kind == "memmap":
        return MemmapTokens(cfg)
    raise ValueError(cfg.kind)
