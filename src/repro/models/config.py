"""Unified architecture configuration for all assigned model families."""

from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_d_ff: int = 0           # per-expert hidden width
    n_shared_experts: int = 0   # qwen2-moe style always-on experts
    shared_d_ff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / rwkv6)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4

    # hybrid (zamba2): one shared attention+MLP block applied every k layers
    attn_every: int = 0

    # encoder-decoder (seamless-m4t)
    n_enc_layers: int = 0

    # vlm (qwen2-vl)
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)

    # attention behaviour
    rope_theta: float = 500000.0
    sliding_window: int = 0     # >0: attention limited to a local window
    attn_logit_softcap: float = 0.0  # grok-style tanh soft-capping

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_to: int = 256      # embedding rows padded so vocab shards 16x16

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context without full attention?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs accounting)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, Hkv, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
        mlp = 3 * D * F
        norms = 2 * D
        n = 0
        if self.family == "ssm":  # rwkv6: D->D projections, lora decay
            tmix = 5 * D * D + 2 * 64 * D + 7 * D  # r,k,v,g,o + lora + mu/u/base
            cmix = 2 * D * F + D * D + 2 * D       # ck, cv, cr, c_mu
            n = L * (tmix + cmix + norms)
        elif self.family == "hybrid":
            di = self.d_inner
            dssm = (
                D * (2 * di + 2 * self.ssm_state * 0 + 0)
                + di * D
                + 2 * di * self.ssm_state
                + self.n_ssm_heads * 2
            )
            n = L * (dssm + norms) + (attn + mlp + norms)  # one shared block
        else:
            per_layer = attn + norms
            if self.n_experts:
                Fe = self.moe_d_ff
                per_layer += D * self.n_experts  # router
                per_layer += self.n_experts * 3 * D * Fe
                if self.n_shared_experts:
                    per_layer += 3 * D * self.shared_d_ff
            else:
                per_layer += mlp
            n = L * per_layer
            if self.family == "encdec":
                # encoder layers + decoder cross-attention
                n += self.n_enc_layers * (attn + mlp + norms)
                n += L * (attn + D)
        n += V * D  # embeddings
        if not self.tie_embeddings:
            n += V * D
        n += D  # final norm
        return int(n)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-active experts)."""
        if not self.n_experts:
            return self.param_count()
        Fe, D, L = self.moe_d_ff, self.d_model, self.n_layers
        inactive = (self.n_experts - self.n_experts_per_tok) * 3 * D * Fe * L
        return int(self.param_count() - inactive)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(4, max(1, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1))),
        d_ff=128,
        vocab_size=256,
        head_dim=16,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, n_experts_per_tok=2, moe_d_ff=64)
        if cfg.n_shared_experts:
            kw.update(n_shared_experts=2, shared_d_ff=96)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.n_enc_layers:
        kw.update(n_enc_layers=2)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    if cfg.mrope:
        half = kw["head_dim"] // 2
        t = max(1, half // 4)
        rest = half - t
        kw.update(mrope_sections=(t, rest // 2, rest - rest // 2))
    return cfg.with_(**kw)
