"""State-space blocks: Mamba2 (for zamba2) and RWKV6 (Finch).

Mamba2 uses the chunked SSD scan from ``repro.kernels.ssm_scan``; RWKV6 is a
chunk-free linear recurrence over [Dh, Dh] head states with data-dependent
decay (its defining feature), implemented with lax.scan over time chunks.
Both expose decode-step functions carrying O(1)-per-token state -- this is
what makes the ``long_500k`` shape runnable for these families.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ssm_scan import ops as scan_ops

from . import layers

Params = dict[str, Any]


# ======================================================================
# Mamba2
# ======================================================================

def init_mamba2(key, cfg) -> Params:
    """Per-stream input projections (instead of one packed in_proj) so the
    d_inner dim shards cleanly over the tensor-parallel mesh axis."""
    D, Di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 7)
    return {
        "wz": layers._init(ks[0], (D, Di)),
        "wx": layers._init(ks[1], (D, Di)),
        "wB": layers._init(ks[2], (D, N)),
        "wC": layers._init(ks[3], (D, N)),
        "wdt": layers._init(ks[4], (D, H)),
        "w_out": layers._init(ks[5], (Di, D), scale=1.0 / math.sqrt(Di)),
        "conv_w": layers._init(ks[6], (cfg.conv_width, Di), scale=0.5),
        "A_log": jnp.log(
            jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)
        ),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
    }


def _split_mamba(p, u, cfg):
    dt_ = u.dtype
    z = u @ p["wz"].astype(dt_)
    x = u @ p["wx"].astype(dt_)
    Bv = u @ p["wB"].astype(dt_)
    Cv = u @ p["wC"].astype(dt_)
    dt = u @ p["wdt"].astype(dt_)
    return z, x, Bv, Cv, dt


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over time.  x: [B,S,Di]; w: [W,Di]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i:i + x.shape[1]] * w[i][None, None, :]
    return out


def mamba2(p: Params, u: jax.Array, cfg, return_state: bool = False):
    """u: [B, S, D] -> [B, S, D]  (optionally also the decode state)."""
    B, S, D = u.shape
    Di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, x, Bv, Cv, dt = _split_mamba(p, u, cfg)
    x_raw = x
    x = jax.nn.silu(_causal_conv(x, p["conv_w"].astype(x.dtype)))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # [B,S,H]
    A = -jnp.exp(p["A_log"])                                             # [H]
    xh = x.reshape(B, S, H, P)
    y = scan_ops.selective_scan(xh, dt, A, Bv, Cv, p["D"])               # [B,S,H,P]
    out = (y.reshape(B, S, Di) * jax.nn.silu(z)) @ p["w_out"].astype(u.dtype)
    if not return_state:
        return out
    ssm_state = scan_ops.final_state(xh, dt, A, Bv)
    W = cfg.conv_width
    if S >= W - 1:
        conv_tail = x_raw[:, S - (W - 1):]
    else:
        conv_tail = jnp.pad(x_raw, ((0, 0), (W - 1 - S, 0), (0, 0)))
    return out, (conv_tail, ssm_state)


def mamba2_decode(p: Params, u: jax.Array, state, cfg):
    """u: [B, 1, D]; state = (conv_buf [B,W-1,Di], ssm [B,H,N,P])."""
    B = u.shape[0]
    Di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_buf, ssm_state = state
    z, x, Bv, Cv, dt = _split_mamba(p, u, cfg)
    # causal conv over [conv_buf, x]
    W = cfg.conv_width
    xw = jnp.concatenate([conv_buf, x], axis=1)                          # [B,W,Di]
    w = p["conv_w"].astype(x.dtype)
    xc = jnp.einsum("bwd,wd->bd", xw, w)[:, None, :]
    x = jax.nn.silu(xc)
    conv_buf = xw[:, 1:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]    # [B,H]
    A = -jnp.exp(p["A_log"])
    y, ssm_state = scan_ops.decode_step(
        x.reshape(B, H, P), dt, A, Bv[:, 0], Cv[:, 0], p["D"], ssm_state
    )
    y = y.reshape(B, 1, Di) * jax.nn.silu(z)
    return y @ p["w_out"].astype(u.dtype), (conv_buf, ssm_state)


def mamba2_state_init(cfg, batch: int, dtype=jnp.float32):
    return (
        jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        jnp.zeros(
            (batch, cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
            jnp.float32,
        ),
    )


# ======================================================================
# RWKV6 (Finch)
# ======================================================================

RWKV_LORA = 64


def init_rwkv6(key, cfg) -> Params:
    D = cfg.d_model
    H = cfg.n_ssm_heads if cfg.ssm_head_dim else 32
    ks = jax.random.split(key, 10)
    return {
        "mu": layers._init(ks[0], (5, D), scale=0.1),     # token-shift mixes
        "wr": layers._init(ks[1], (D, D)),
        "wk": layers._init(ks[2], (D, D)),
        "wv": layers._init(ks[3], (D, D)),
        "wg": layers._init(ks[4], (D, D)),
        "wo": layers._init(ks[5], (D, D)),
        # data-dependent decay: w_t = exp(-exp(base + lora(x)))
        "w_base": jnp.full((D,), -1.0, jnp.float32),
        "w_lora_a": layers._init(ks[6], (D, RWKV_LORA)),
        "w_lora_b": layers._init(ks[7], (RWKV_LORA, D), scale=0.01),
        "u": layers._init(ks[8], (D,), scale=0.5),        # bonus
        # channel-mix
        "ck": layers._init(ks[9], (D, cfg.d_ff)),
        "cv": layers._init(jax.random.fold_in(key, 11), (cfg.d_ff, D)),
        "cr": layers._init(jax.random.fold_in(key, 12), (D, D)),
        "c_mu": layers._init(jax.random.fold_in(key, 13), (2, D), scale=0.1),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """x_{t-1} stream; prev is the carry token for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x.shape[1] > 1:
        return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)
    return prev[:, None]


def _rwkv_wkv(r, k, v, w, u, head_dim: int, state=None, chunk: int = 64):
    """RWKV6 linear recurrence.

    r,k,v,w: [B,S,D] (w = per-step decay in (0,1)); u: [D] bonus.
    state: [B,H,Dh,Dh] or None.  Returns (y [B,S,D], final_state).

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    """
    B, S, D = r.shape
    Dh = head_dim
    H = D // Dh
    rh = r.reshape(B, S, H, Dh)
    kh = k.reshape(B, S, H, Dh)
    vh = v.reshape(B, S, H, Dh)
    wh = w.reshape(B, S, H, Dh)
    uh = u.reshape(H, Dh)
    if state is None:
        state = jnp.zeros((B, H, Dh, Dh), jnp.float32)

    def step(s, xs):
        rt, kt, vt, wt = xs                               # [B,H,Dh]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + uh[None, :, :, None] * kv)
        s = s * wt[..., None] + kv
        return s, y

    xs = (
        jnp.moveaxis(rh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(kh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(vh.astype(jnp.float32), 1, 0),
        jnp.moveaxis(wh.astype(jnp.float32), 1, 0),
    )
    state, ys = lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, D)
    return y.astype(r.dtype), state


def rwkv6_time_mix(p: Params, x: jax.Array, cfg, shift_prev=None, state=None):
    """Returns (y, (last_token, new_state))."""
    xs = _token_shift(x, shift_prev)
    mu = p["mu"].astype(x.dtype)
    xr = x + (xs - x) * mu[0]
    xk = x + (xs - x) * mu[1]
    xv = x + (xs - x) * mu[2]
    xg = x + (xs - x) * mu[3]
    xw = x + (xs - x) * mu[4]
    r = xr @ p["wr"].astype(x.dtype)
    k = xk @ p["wk"].astype(x.dtype)
    v = xv @ p["wv"].astype(x.dtype)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    # data-dependent decay (the Finch contribution)
    wlog = p["w_base"] + (
        jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    )
    w = jnp.exp(-jnp.exp(wlog))                           # in (0,1)
    y, state = _rwkv_wkv(
        r, k, v, w.astype(x.dtype), p["u"], cfg.ssm_head_dim, state=state
    )
    y = y * g
    return y @ p["wo"].astype(x.dtype), (x[:, -1], state)


def rwkv6_channel_mix(p: Params, x: jax.Array, shift_prev=None):
    xs = _token_shift(x, shift_prev)
    mu = p["c_mu"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    r = jax.nn.sigmoid(xr @ p["cr"].astype(x.dtype))
    return r * (k @ p["cv"].astype(x.dtype)), x[:, -1]


def rwkv6_state_init(cfg, batch: int, dtype=jnp.float32):
    D, Dh = cfg.d_model, cfg.ssm_head_dim
    H = D // Dh
    return {
        "tm_shift": jnp.zeros((batch, D), dtype),
        "tm_state": jnp.zeros((batch, H, Dh, Dh), jnp.float32),
        "cm_shift": jnp.zeros((batch, D), dtype),
    }
