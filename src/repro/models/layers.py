"""Core transformer layers, pure-functional JAX.

Parameters are plain nested dicts of arrays; each ``init_*`` has a matching
``apply`` function.  Layer stacks are stored stacked on a leading ``L`` dim
and consumed by ``lax.scan`` so the compiled HLO stays one-layer-sized.

Attention uses the flash kernel from ``repro.kernels`` when profitable and
the pure-jnp reference otherwise (decode, tiny shapes, cross-attention).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.rmsnorm import ops as rmsnorm_ops

Params = dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return jax.random.normal(key, shape, dtype) * jnp.asarray(scale, dtype)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------

def init_rmsnorm(d: int) -> Params:
    return {"w": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    return rmsnorm_ops.rmsnorm(x, p["w"].astype(x.dtype), eps=eps)


# ----------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, Dh]; positions: [B, S] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)             # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.  positions: [3, B, S] (t, h, w grids);
    frequency slots are split between the three position streams by
    ``sections`` (summing to Dh/2)."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)             # [Dh/2]
    ang_thw = positions[..., None].astype(jnp.float32) * freqs  # [3, B, S, Dh/2]
    idx = []
    for i, sec in enumerate(sections):
        idx += [i] * sec
    sel = jnp.asarray(idx)                              # [Dh/2] in {0,1,2}
    ang = jnp.take_along_axis(
        ang_thw, sel[None, None, None, :].repeat(ang_thw.shape[1], 1).repeat(
            ang_thw.shape[2], 2
        ), axis=0
    )[0]                                                # [B, S, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# ----------------------------------------------------------------------
# GQA attention
# ----------------------------------------------------------------------

def init_attention(key, cfg) -> Params:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _init(ks[0], (D, H * Dh)),
        "wk": _init(ks[1], (D, Hkv * Dh)),
        "wv": _init(ks[2], (D, Hkv * Dh)),
        "wo": _init(ks[3], (H * Dh, D), scale=1.0 / math.sqrt(H * Dh)),
    }


def _qkv(p: Params, x: jax.Array, cfg):
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, Hkv, Dh)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, Hkv, Dh)
    return q, k, v


def _rotate(q, k, positions, cfg):
    if cfg.mrope and positions.ndim == 3:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg,
    causal: bool = True,
    use_kernel: bool = True,
) -> jax.Array:
    """Self-attention over full sequences (train / prefill)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    q, k = _rotate(q, k, positions, cfg)
    o = flash_ops.mha(
        q,
        k,
        v,
        causal=causal,
        logit_softcap=cfg.attn_logit_softcap,
        sliding_window=cfg.sliding_window,
        use_kernel=use_kernel,
    )
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def cross_attention(
    p: Params, x: jax.Array, kv_src: jax.Array, cfg
) -> jax.Array:
    """Encoder-decoder cross attention (no RoPE on the cross path)."""
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, H, Dh)
    k = (kv_src @ p["wk"].astype(x.dtype)).reshape(B, kv_src.shape[1], Hkv, Dh)
    v = (kv_src @ p["wv"].astype(x.dtype)).reshape(B, kv_src.shape[1], Hkv, Dh)
    o = flash_ops.mha(q, k, v, causal=False, use_kernel=False)
    return o.reshape(B, S, -1) @ p["wo"].astype(x.dtype)


def attention_decode(
    p: Params,
    x: jax.Array,
    cache_k: jax.Array,
    cache_v: jax.Array,
    position: jax.Array,
    cfg,
):
    """One-token decode against a KV cache (ring buffer when the cache is
    shorter than the context, i.e. sliding-window attention).

    x: [B, 1, D]; cache_k/v: [B, S_cache, Hkv, Dh]; position: [] scalar int.
    Returns (out [B, 1, D], new_cache_k, new_cache_v).

    Ring semantics: the K/V for absolute position t live in slot t % S_cache.
    Keys are stored post-RoPE, so scores only need slot-validity masking:
    slot j is valid iff j <= position (before wrap) or always (after wrap) --
    uniformly ``arange(S_cache) <= position``.  The window constraint is
    implied: a ring of size W holds exactly the last W tokens.
    """
    B = x.shape[0]
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q, k, v = _qkv(p, x, cfg)
    pos = jnp.full((B, 1), position, jnp.int32)
    q, k = _rotate(q, k, pos if not cfg.mrope else _mrope_pos(pos), cfg)
    S = cache_k.shape[1]
    slot = position % S
    cache_k = lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0)
    )
    cache_v = lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0)
    )
    g = H // Hkv
    qh = q.reshape(B, 1, Hkv, g, Dh)
    logits = jnp.einsum(
        "bthgd,bshd->bhgts",
        qh,
        cache_k.astype(qh.dtype),
        preferred_element_type=jnp.float32,
    ) / math.sqrt(Dh)
    if cfg.attn_logit_softcap:
        logits = cfg.attn_logit_softcap * jnp.tanh(logits / cfg.attn_logit_softcap)
    span = jnp.arange(S)
    mask = span <= position
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bhgts,bshd->bthgd", w, cache_v)
    o = o.reshape(B, 1, H * Dh).astype(x.dtype)
    return o @ p["wo"].astype(x.dtype), cache_k, cache_v


def _mrope_pos(pos: jax.Array) -> jax.Array:
    """Text-only decode: all three M-RoPE streams share the position."""
    return jnp.broadcast_to(pos[None], (3, *pos.shape))


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _init(ks[0], (d_model, d_ff)),
        "w_up": _init(ks[1], (d_model, d_ff)),
        "w_down": _init(ks[2], (d_ff, d_model)),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (x @ p["w_up"].astype(x.dtype))
    return h @ p["w_down"].astype(x.dtype)


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, tie: bool,
               padded_vocab: int | None = None) -> Params:
    """Embedding table padded to ``padded_vocab`` rows so the vocab dim
    shards over the 16-wide tensor-parallel axis for every arch (Megatron-
    style); the pad columns are masked to -inf at the logits."""
    Vp = padded_vocab or vocab
    ks = jax.random.split(key, 2)
    p = {"tok": _init(ks[0], (Vp, d_model), scale=1.0)}
    if not tie:
        p["unembed"] = _init(ks[1], (d_model, Vp))
    return p


def embed(p: Params, tokens: jax.Array, dtype,
          table_axis: str | None = "data") -> jax.Array:
    """Token embedding lookup.

    The table is resharded to (vocab-replicated, d_model over ``table_axis``)
    before the gather: a gather whose dim-0 operand is vocab-sharded forces
    the partitioner into mask+psum or full-rematerialization reshards (the
    latter crosses the pod seam on multi-pod meshes).  With the operand
    sharded only on the pass-through D dim and indices batch-sharded, the
    gather is fully local; the small reshard stays on the intra-pod ICI
    tier.  table_axis=None replicates the table (dp256 policy: the batch
    owns both mesh axes; only used for small-vocab-footprint archs).
    """
    tok = p["tok"]
    try:
        from jax.sharding import PartitionSpec as _P

        tok = jax.lax.with_sharding_constraint(tok, _P(None, table_axis))
    except (ValueError, RuntimeError, TypeError):
        pass
    return tok.astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array, vocab_size: int | None = None) -> jax.Array:
    if "unembed" in p:
        logits = x @ p["unembed"].astype(x.dtype)
    else:
        logits = x @ p["tok"].T.astype(x.dtype)
    Vp = logits.shape[-1]
    if vocab_size is not None and Vp != vocab_size:
        # mask pad columns; keeps the padded (sharded) width end to end
        mask = jnp.arange(Vp) < vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits
