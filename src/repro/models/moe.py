"""Mixture-of-Experts layer with capacity-based dense dispatch.

TPU-native formulation (Mesh-TensorFlow style): instead of ragged gathers,
tokens are routed into a [tokens, E, capacity] one-hot dispatch tensor and
experts run as one batched einsum over [E, capacity, ...].  Compiled FLOPs
scale with top_k * capacity_factor (not with E), keeping the useful-FLOPs
ratio high; overflowing tokens are dropped by capacity (standard).

Expert-parallelism: the expert hidden dim shards over the 'model' mesh axis
(every assigned arch's moe_d_ff divides 16); the dispatch einsums produce
the all-to-all-shaped exchange that ``core.planner`` costs with the paper's
model when EP spans machines.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers

Params = dict[str, Any]


def init_moe(key, cfg) -> Params:
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": layers._init(ks[0], (D, E)),
        "w_gate": layers._init(ks[1], (E, D, Fe)),
        "w_up": layers._init(ks[2], (E, D, Fe)),
        "w_down": layers._init(ks[3], (E, Fe, D), scale=1.0 / math.sqrt(Fe)),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_mlp(ks[4], D, cfg.shared_d_ff)
    return p


MOE_GROUP = 2048  # tokens per dispatch group


def _capacity(n_tokens: int, cfg) -> int:
    cap = int(
        math.ceil(
            n_tokens * cfg.n_experts_per_tok * cfg.capacity_factor
            / cfg.n_experts
        )
    )
    return max(cap, 1)


def _dispatch_group(xt, probs, cfg, dtype):
    """One group's capacity dispatch.  xt: [T, D]; probs: [T, E] f32."""
    T = xt.shape[0]
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    C = _capacity(T, cfg)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                        # [T, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)                # [T,K,E]
    flat = onehot.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                            # [T*K,E]
    pos = pos.reshape(T, K, E)
    in_cap = (pos >= 0) & (pos < C)
    pos_clip = jnp.clip(pos, 0, C - 1)
    cap_onehot = jax.nn.one_hot(pos_clip, C, dtype=dtype)                # [T,K,E,C]
    disp = (cap_onehot * (onehot * in_cap)[..., None].astype(dtype)).sum(1)
    comb = (
        cap_onehot
        * ((onehot * in_cap).astype(jnp.float32) * gate_vals[..., None])[..., None]
    ).sum(1).astype(dtype)                                               # [T,E,C]
    xe = jnp.einsum("td,tec->ecd", xt, disp)                             # [E,C,D]
    return xe, comb, disp


def moe(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss).

    Router in f32; GROUP-WISE top-k capacity dispatch (groups of MOE_GROUP
    tokens): capacity scales with the group, not the global batch, so
    dispatch memory is O(T * E * C_group) with C_group a constant -- the
    global form is quadratic in tokens and melts HBM at 32k prefill.
    Experts run as one batched einsum over all groups (MXU-dense; FLOPs
    scale with top_k, not E).  Load-balance aux loss is Switch-style,
    averaged over groups.
    """
    B, S, D = x.shape
    E = cfg.n_experts
    T = B * S
    gs = min(MOE_GROUP, T)
    G = T // gs
    if T % gs:
        # fall back to one group (tiny inputs in tests)
        gs, G = T, 1
    xt = x.reshape(G, gs, D)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)    # [G,gs,E]
    probs = jax.nn.softmax(logits, axis=-1)

    xe, comb, disp = jax.vmap(
        lambda xg, pg: _dispatch_group(xg, pg, cfg, x.dtype)
    )(xt, probs)                                                          # [G,E,C,D]

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    y = jnp.einsum("gecd,gtec->gtd", ye, comb)

    if cfg.n_shared_experts:
        y = y + layers.mlp(p["shared"], xt)

    # Switch-transformer load-balance loss (mean over groups)
    me = jnp.mean(probs, axis=1)                                          # [G,E]
    ce = jnp.mean(disp.astype(jnp.float32).sum(-1), axis=1)               # [G,E]
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))
    return y.reshape(B, S, D), aux
