"""Unified language model over all assigned families.

One parameter tree + three entry points per family:

  * ``forward``      -- full-sequence logits (training / prefill math)
  * ``prefill``      -- forward + populated decode cache
  * ``decode_step``  -- one token against the cache

Layer stacks are stored stacked on a leading L dim and consumed by
``lax.scan`` (compact HLO: the 512-device dry-run lowers one layer body,
not n_layers copies).  Gradient checkpointing wraps the scan body with a
configurable policy.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from jax.sharding import PartitionSpec as P

from . import layers, moe as moe_mod, ssm
from .config import ModelConfig

Params = dict[str, Any]


def _cast_big_params(params: Params, dt) -> Params:
    """Cast large f32 parameter matrices to the compute dtype ONCE, before
    the layer scan.  Downstream effects on the compiled collectives:

      * FSDP all-gathers move bf16 shards (2x fewer bytes than gathering
        f32 then casting inside the layer, which is what per-layer
        ``w.astype(x.dtype)`` lowers to);
      * the per-microbatch gradient reduce-scatters run on bf16 cotangents
        (the transpose of the cast converts to f32 only at the local
        accumulator).

    Small leaves (norm scales, A_log, dt_bias, mu vectors) stay f32: their
    bytes are irrelevant and their math wants full precision."""
    if dt == jnp.float32:
        return params
    return jax.tree.map(
        lambda a: a.astype(dt)
        if (hasattr(a, "dtype") and a.dtype == jnp.float32 and a.size > 1_000_000)
        else a,
        params,
    )


def _table_axis(batch_axes):
    """Embedding-table D-dim home for the lookup reshard."""
    if batch_axes and "model" in batch_axes:
        return None          # dp256: batch owns both axes; replicate table
    return "data"


def _constrain(x: jax.Array, batch_axes) -> jax.Array:
    """Pin activation sharding: batch dim over ``batch_axes``, rest
    propagated.  Without this, parameter shardings (e.g. the embedding
    table's fsdp dim) leak into activations and batch parallelism is lost.
    No-op when no mesh context is active (single-device tests)."""
    if batch_axes is None:
        return x
    try:
        spec = P(batch_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError, TypeError):
        return x

REMAT_POLICIES = {
    "none": None,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def _cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ======================================================================
# Init
# ======================================================================

def _init_dense_block(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "norm1": layers.init_rmsnorm(cfg.d_model),
        "attn": layers.init_attention(ks[0], cfg),
        "norm2": layers.init_rmsnorm(cfg.d_model),
    }
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    return p


def _init_encdec_dec_block(key, cfg) -> Params:
    ks = jax.random.split(key, 3)
    p = _init_dense_block(ks[0], cfg)
    p["norm_x"] = layers.init_rmsnorm(cfg.d_model)
    p["xattn"] = layers.init_attention(ks[1], cfg)
    return p


def _init_mamba_block(key, cfg) -> Params:
    return {
        "norm": layers.init_rmsnorm(cfg.d_model),
        "mamba": ssm.init_mamba2(key, cfg),
    }


def _init_rwkv_block(key, cfg) -> Params:
    return {
        "norm1": layers.init_rmsnorm(cfg.d_model),
        "norm2": layers.init_rmsnorm(cfg.d_model),
        "rwkv": ssm.init_rwkv6(key, cfg),
    }


def _stack(blocks: list[Params]) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)


def init_params(key: jax.Array, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + cfg.n_enc_layers + 8)
    p: Params = {
        "embed": layers.init_embed(ks[-1], cfg.vocab_size, cfg.d_model,
                                   cfg.tie_embeddings,
                                   padded_vocab=cfg.padded_vocab),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }
    if cfg.family in ("dense", "moe", "vlm"):
        p["blocks"] = _stack(
            [_init_dense_block(ks[i], cfg) for i in range(cfg.n_layers)]
        )
    elif cfg.family == "ssm":
        p["blocks"] = _stack(
            [_init_rwkv_block(ks[i], cfg) for i in range(cfg.n_layers)]
        )
    elif cfg.family == "hybrid":
        p["blocks"] = _stack(
            [_init_mamba_block(ks[i], cfg) for i in range(cfg.n_layers)]
        )
        p["shared_attn"] = _init_dense_block(ks[-2], cfg)
    elif cfg.family == "encdec":
        p["enc_blocks"] = _stack(
            [
                _init_dense_block(ks[cfg.n_layers + i], cfg)
                for i in range(cfg.n_enc_layers)
            ]
        )
        p["blocks"] = _stack(
            [_init_encdec_dec_block(ks[i], cfg) for i in range(cfg.n_layers)]
        )
    else:
        raise ValueError(cfg.family)
    return p


# ======================================================================
# Full-sequence forward
# ======================================================================

def _dense_block_fwd(p, x, positions, cfg, use_kernel=True):
    h = layers.rmsnorm(p["norm1"], x, cfg.norm_eps)
    x = x + layers.attention(p["attn"], h, positions, cfg, use_kernel=use_kernel)
    h = layers.rmsnorm(p["norm2"], x, cfg.norm_eps)
    if cfg.n_experts:
        y, aux = moe_mod.moe(p["moe"], h, cfg)
        return x + y, aux
    return x + layers.mlp(p["mlp"], h), jnp.zeros((), jnp.float32)


def _scan_blocks(body, x, blocks, cfg, remat: str):
    policy = REMAT_POLICIES.get(remat, None)
    if remat != "none":
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)
    return lax.scan(body, x, blocks)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array | None = None,
    embeds: jax.Array | None = None,
    positions: jax.Array | None = None,
    enc_embeds: jax.Array | None = None,
    remat: str = "nothing",
    use_kernel: bool = True,
    batch_axes=None,
):
    """-> (logits f32 [B,S,V], aux_loss scalar)."""
    dt = _cdtype(cfg)
    params = _cast_big_params(params, dt)
    x = (
        layers.embed(params["embed"], tokens, dt, _table_axis(batch_axes))
        if embeds is None
        else embeds.astype(dt)
    )
    x = _constrain(x, batch_axes)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[None], (3, B, S))

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, pl_):
            y, aux = _dense_block_fwd(
                pl_, _constrain(carry, batch_axes), positions, cfg, use_kernel
            )
            return _constrain(y, batch_axes), aux
        x, auxs = _scan_blocks(body, x, params["blocks"], cfg, remat)
        aux = jnp.sum(auxs)

    elif cfg.family == "ssm":
        def body(carry, pl_):
            carry = _constrain(carry, batch_axes)
            h = layers.rmsnorm(pl_["norm1"], carry, cfg.norm_eps)
            y, _ = ssm.rwkv6_time_mix(pl_["rwkv"], h, cfg)
            carry = carry + y
            h = layers.rmsnorm(pl_["norm2"], carry, cfg.norm_eps)
            y, _ = ssm.rwkv6_channel_mix(pl_["rwkv"], h)
            return carry + y, jnp.zeros((), jnp.float32)
        x, auxs = _scan_blocks(body, x, params["blocks"], cfg, remat)
        aux = jnp.sum(auxs)

    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.attn_every, *a.shape[1:]), params["blocks"]
        )
        shared = params["shared_attn"]

        def group_body(carry, pg):
            def inner(c, pl_):
                c = _constrain(c, batch_axes)
                h = layers.rmsnorm(pl_["norm"], c, cfg.norm_eps)
                return c + ssm.mamba2(pl_["mamba"], h, cfg), None
            x_, _ = lax.scan(inner, _constrain(carry, batch_axes), pg)
            y_, _ = _dense_block_fwd(shared, x_, positions, cfg, use_kernel)
            return y_, jnp.zeros((), jnp.float32)

        x, auxs = _scan_blocks(group_body, x, grouped, cfg, remat)
        aux = jnp.sum(auxs)

    elif cfg.family == "encdec":
        assert enc_embeds is not None, "encdec needs encoder frame embeddings"
        e = _constrain(enc_embeds.astype(dt), batch_axes)
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])

        def enc_body(carry, pl_):
            carry = _constrain(carry, batch_axes)
            h = layers.rmsnorm(pl_["norm1"], carry, cfg.norm_eps)
            carry = carry + layers.attention(
                pl_["attn"], h, epos, cfg, causal=False, use_kernel=use_kernel
            )
            h = layers.rmsnorm(pl_["norm2"], carry, cfg.norm_eps)
            return carry + layers.mlp(pl_["mlp"], h), None

        e, _ = _scan_blocks(
            lambda c, p_: enc_body(c, p_), e, params["enc_blocks"], cfg, remat
        )

        def dec_body(carry, pl_):
            carry = _constrain(carry, batch_axes)
            h = layers.rmsnorm(pl_["norm1"], carry, cfg.norm_eps)
            carry = carry + layers.attention(
                pl_["attn"], h, positions, cfg, use_kernel=use_kernel
            )
            h = layers.rmsnorm(pl_["norm_x"], carry, cfg.norm_eps)
            carry = carry + layers.cross_attention(pl_["xattn"], h, e, cfg)
            h = layers.rmsnorm(pl_["norm2"], carry, cfg.norm_eps)
            return carry + layers.mlp(pl_["mlp"], h), None

        x, _ = _scan_blocks(dec_body, x, params["blocks"], cfg, remat)
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg.vocab_size).astype(jnp.float32)
    return logits, aux


# ======================================================================
# Decode cache
# ======================================================================

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0) -> Params:
    """Decode state for every family.  Attention caches are bf16."""
    dt = _cdtype(cfg)
    Hkv, Dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["k"] = jnp.zeros((L, batch, kv_len, Hkv, Dh), dt)
        cache["v"] = jnp.zeros((L, batch, kv_len, Hkv, Dh), dt)
        if cfg.family == "encdec":
            cache["xk"] = jnp.zeros((L, batch, enc_len, Hkv, Dh), dt)
            cache["xv"] = jnp.zeros((L, batch, enc_len, Hkv, Dh), dt)
    elif cfg.family == "hybrid":
        # the shared attention block is applied once per layer-group; each
        # application attends over its own depth's history => per-group caches
        G = cfg.n_layers // cfg.attn_every
        kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        cache["k"] = jnp.zeros((G, batch, kv_len, Hkv, Dh), dt)
        cache["v"] = jnp.zeros((G, batch, kv_len, Hkv, Dh), dt)
        conv, st = ssm.mamba2_state_init(cfg, batch, dt)
        cache["conv"] = jnp.broadcast_to(conv, (L, *conv.shape))
        cache["ssm"] = jnp.broadcast_to(st, (L, *st.shape))
    elif cfg.family == "ssm":
        s = ssm.rwkv6_state_init(cfg, batch, dt)
        cache = {"pos": cache["pos"]} | {
            k: jnp.broadcast_to(v, (L, *v.shape)) for k, v in s.items()
        }
    return cache


# ======================================================================
# One-token decode
# ======================================================================

def decode_step(
    params: Params, cfg: ModelConfig, tokens: jax.Array, cache: Params,
    batch_axes=None,
):
    """tokens: [B] int32 -> (logits [B, V] f32, new cache)."""
    dt = _cdtype(cfg)
    x = layers.embed(params["embed"], tokens[:, None], dt,
                     _table_axis(batch_axes))   # [B,1,D]
    x = _constrain(x, batch_axes)
    pos = cache["pos"]

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            c = _constrain(carry, batch_axes)
            pl_, ck, cv = xs
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            a, ck, cv = layers.attention_decode(pl_["attn"], h, ck, cv, pos, cfg)
            c = c + a
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            if cfg.n_experts:
                y, _ = moe_mod.moe(pl_["moe"], h, cfg)
                c = c + y
            else:
                c = c + layers.mlp(pl_["mlp"], h)
            return c, (ck, cv)

        x, (ks, vs) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family == "ssm":
        def body(carry, xs):
            c = _constrain(carry, batch_axes)
            pl_, tm_shift, tm_state, cm_shift = xs
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            y, (tm_shift, tm_state) = ssm.rwkv6_time_mix(
                pl_["rwkv"], h, cfg, shift_prev=tm_shift, state=tm_state
            )
            c = c + y
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            y, cm_shift = ssm.rwkv6_channel_mix(pl_["rwkv"], h, shift_prev=cm_shift)
            return c + y, (tm_shift, tm_state, cm_shift)

        x, (tms, tmst, cms) = lax.scan(
            body,
            x,
            (
                params["blocks"],
                cache["tm_shift"],
                cache["tm_state"],
                cache["cm_shift"],
            ),
        )
        cache = dict(cache, tm_shift=tms, tm_state=tmst, cm_shift=cms)

    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        grouped_blocks, grouped_conv, grouped_ssm = jax.tree.map(
            lambda a: a.reshape(G, cfg.attn_every, *a.shape[1:]),
            (params["blocks"], cache["conv"], cache["ssm"]),
        )
        shared = params["shared_attn"]

        def group_body(carry, xs):
            c = _constrain(carry, batch_axes)
            pg, convg, ssmg, ckg, cvg = xs

            def inner(c_, xs_):
                pl_, conv1, ssm1 = xs_
                h = layers.rmsnorm(pl_["norm"], c_, cfg.norm_eps)
                y, (conv1, ssm1) = ssm.mamba2_decode(
                    pl_["mamba"], h, (conv1, ssm1), cfg
                )
                return c_ + y, (conv1, ssm1)

            c, (convg, ssmg) = lax.scan(inner, c, (pg, convg, ssmg))
            # shared attention block (params shared, per-group KV cache)
            h = layers.rmsnorm(shared["norm1"], c, cfg.norm_eps)
            a, ckg, cvg = layers.attention_decode(shared["attn"], h, ckg, cvg, pos, cfg)
            c = c + a
            h = layers.rmsnorm(shared["norm2"], c, cfg.norm_eps)
            c = c + layers.mlp(shared["mlp"], h)
            return c, (convg, ssmg, ckg, cvg)

        x, (convs, ssms, ks, vs) = lax.scan(
            group_body,
            x,
            (grouped_blocks, grouped_conv, grouped_ssm, cache["k"], cache["v"]),
        )
        cache = dict(
            cache,
            conv=convs.reshape(cfg.n_layers, *convs.shape[2:]),
            ssm=ssms.reshape(cfg.n_layers, *ssms.shape[2:]),
            k=ks,
            v=vs,
        )

    elif cfg.family == "encdec":
        def body(carry, xs):
            c = _constrain(carry, batch_axes)
            pl_, ck, cv, xk, xv = xs
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            a, ck, cv = layers.attention_decode(pl_["attn"], h, ck, cv, pos, cfg)
            c = c + a
            h = layers.rmsnorm(pl_["norm_x"], c, cfg.norm_eps)
            c = c + _xattn_cached(pl_["xattn"], h, xk, xv, cfg)
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            return c + layers.mlp(pl_["mlp"], h), (ck, cv)

        x, (ks, vs) = lax.scan(
            body, x,
            (params["blocks"], cache["k"], cache["v"], cache["xk"], cache["xv"]),
        )
        cache = dict(cache, k=ks, v=vs)
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed(params["embed"], x, cfg.vocab_size).astype(jnp.float32)
    cache["pos"] = pos + 1
    return logits[:, 0], cache


def _xattn_cached(p, x, xk, xv, cfg):
    import math as _math
    B, S, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = H // Hkv
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, Hkv, g, Dh)
    logits = jnp.einsum(
        "bthgd,bshd->bhgts", q, xk.astype(q.dtype),
        preferred_element_type=jnp.float32,
    ) / _math.sqrt(Dh)
    w = jax.nn.softmax(logits, -1).astype(xv.dtype)
    o = jnp.einsum("bhgts,bshd->bthgd", w, xv).reshape(B, S, H * Dh)
    return o.astype(x.dtype) @ p["wo"].astype(x.dtype)


# ======================================================================
# Prefill (populate cache then decode)
# ======================================================================

def _fill_kv(ck: jax.Array, k: jax.Array) -> jax.Array:
    """Write post-RoPE K (or V) [B,S,...] into a cache [B,kv_len,...].

    When the cache is a ring (kv_len < S) keep the last kv_len entries at
    their ring slots (absolute position t -> slot t % kv_len)."""
    kv_len, S = ck.shape[1], k.shape[1]
    if S >= kv_len:
        last = k[:, S - kv_len:]
        return jnp.roll(last, S % kv_len, axis=1).astype(ck.dtype)
    return lax.dynamic_update_slice(
        ck, k.astype(ck.dtype), (0,) * ck.ndim
    )


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array | None,
    cache: Params,
    embeds: jax.Array | None = None,
    enc_embeds: jax.Array | None = None,
    use_kernel: bool = True,
    batch_axes=None,
):
    """Full-sequence forward that also fills the decode cache.

    The cache is populated inside the same layer scan as the forward pass
    (no second pass); numerical hand-off to ``decode_step`` is verified in
    tests for every family.
    """
    from repro.kernels.flash_attention import ops as fops

    dt = _cdtype(cfg)
    x = (
        layers.embed(params["embed"], tokens, dt, _table_axis(batch_axes))
        if embeds is None
        else embeds.astype(dt)
    )
    x = _constrain(x, batch_axes)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.mrope:
        positions = jnp.broadcast_to(positions[None], (3, B, S))

    def _attn_fill(pl_attn, h, ck, cv, causal=True):
        q, k, v = layers._qkv(pl_attn, h, cfg)
        q, k = layers._rotate(q, k, positions, cfg)
        a = fops.mha(
            q, k, v, causal=causal, logit_softcap=cfg.attn_logit_softcap,
            sliding_window=cfg.sliding_window, use_kernel=use_kernel,
        ).reshape(B, S, -1) @ pl_attn["wo"].astype(h.dtype)
        return a, _fill_kv(ck, k), _fill_kv(cv, v)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, xs):
            c = _constrain(carry, batch_axes)
            pl_, ck, cv = xs
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            a, ck, cv = _attn_fill(pl_["attn"], h, ck, cv)
            c = c + a
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            if cfg.n_experts:
                y, _ = moe_mod.moe(pl_["moe"], h, cfg)
                c = c + y
            else:
                c = c + layers.mlp(pl_["mlp"], h)
            return c, (ck, cv)

        x, (ks, vs) = lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family == "ssm":
        def body(carry, pl_):
            c = _constrain(carry, batch_axes)
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            y, (tm_shift, tm_state) = ssm.rwkv6_time_mix(pl_["rwkv"], h, cfg)
            c = c + y
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            y, cm_shift = ssm.rwkv6_channel_mix(pl_["rwkv"], h)
            return c + y, (tm_shift, tm_state, cm_shift)

        x, (tms, tmst, cms) = lax.scan(body, x, params["blocks"])
        cache = dict(
            cache, tm_shift=tms.astype(dt), tm_state=tmst,
            cm_shift=cms.astype(dt),
        )

    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        grouped = jax.tree.map(
            lambda a: a.reshape(G, cfg.attn_every, *a.shape[1:]), params["blocks"]
        )
        shared = params["shared_attn"]

        def group_body(carry, xs):
            c = _constrain(carry, batch_axes)
            pg, ckg, cvg = xs

            def inner(c_, pl_):
                c_ = _constrain(c_, batch_axes)
                h = layers.rmsnorm(pl_["norm"], c_, cfg.norm_eps)
                y, st = ssm.mamba2(pl_["mamba"], h, cfg, return_state=True)
                return c_ + y, st

            c, (convs, ssms) = lax.scan(inner, c, pg)
            h = layers.rmsnorm(shared["norm1"], c, cfg.norm_eps)
            a, ckg, cvg = _attn_fill(shared["attn"], h, ckg, cvg)
            c = c + a
            h = layers.rmsnorm(shared["norm2"], c, cfg.norm_eps)
            c = c + layers.mlp(shared["mlp"], h)
            return c, (convs, ssms, ckg, cvg)

        x, (convs, ssms, ks, vs) = lax.scan(
            group_body, x, (grouped, cache["k"], cache["v"])
        )
        cache = dict(
            cache,
            conv=convs.reshape(cfg.n_layers, *convs.shape[2:]).astype(dt),
            ssm=ssms.reshape(cfg.n_layers, *ssms.shape[2:]),
            k=ks,
            v=vs,
        )

    elif cfg.family == "encdec":
        assert enc_embeds is not None
        e = enc_embeds.astype(dt)
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])

        def enc_body(carry, pl_):
            c = carry
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            c = c + layers.attention(
                pl_["attn"], h, epos, cfg, causal=False, use_kernel=use_kernel
            )
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            return c + layers.mlp(pl_["mlp"], h), None

        e, _ = lax.scan(enc_body, e, params["enc_blocks"])

        def dec_body(carry, xs):
            c = carry
            pl_, ck, cv = xs
            h = layers.rmsnorm(pl_["norm1"], c, cfg.norm_eps)
            a, ck, cv = _attn_fill(pl_["attn"], h, ck, cv)
            c = c + a
            h = layers.rmsnorm(pl_["norm_x"], c, cfg.norm_eps)
            c = c + layers.cross_attention(pl_["xattn"], h, e, cfg)
            xk = (e @ pl_["xattn"]["wk"].astype(dt)).reshape(
                B, e.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            xv = (e @ pl_["xattn"]["wv"].astype(dt)).reshape(
                B, e.shape[1], cfg.n_kv_heads, cfg.head_dim
            )
            h = layers.rmsnorm(pl_["norm2"], c, cfg.norm_eps)
            return c + layers.mlp(pl_["mlp"], h), (ck, cv, xk, xv)

        x, (ks, vs, xks, xvs) = lax.scan(
            dec_body, x, (params["blocks"], cache["k"], cache["v"])
        )
        cache = dict(cache, k=ks, v=vs, xk=xks.astype(dt), xv=xvs.astype(dt))
    else:
        raise ValueError(cfg.family)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = layers.unembed(params["embed"], x[:, -1:], cfg.vocab_size).astype(
        jnp.float32
    )
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits[:, 0], cache
