"""Checkpointing + fault-tolerance behaviour."""

from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "c": jax.random.normal(k, (3,)) * 2},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    t = _tree()
    ck.save(5, t, blocking=True)
    got, step = ck.restore(jax.tree.map(lambda x: x, t))
    assert step == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in [10, 20, 30]:
        ck.save(s, _tree(s), blocking=True)
    assert ck.latest_step() == 30
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert kept == ["step_00000020", "step_00000030"]


def test_async_save_then_restore(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree(3)
    ck.save(7, t, blocking=False)
    ck.wait()
    got, step = ck.restore(t)
    assert step == 7


def test_corruption_detected(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t, blocking=True)
    # flip bytes in the arrays file
    f = Path(tmp_path) / "step_00000001" / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(Exception):
        ck.restore(t)


def test_truncated_arrays_falls_back_to_previous_step(tmp_path):
    """A committed-but-truncated arrays.npz (crash racing the final fsync)
    must not brick the resume: restore skips it and loads the next-older
    complete checkpoint."""
    ck = Checkpointer(tmp_path, keep=3)
    t = _tree()
    ck.save(1, t, blocking=True)
    ck.save(2, _tree(2), blocking=True)
    f = Path(tmp_path) / "step_00000002" / "arrays.npz"
    f.write_bytes(f.read_bytes()[: f.stat().st_size // 2])
    got, step = ck.restore(t)
    assert step == 1
    for a, b in zip(jax.tree.leaves(_tree()), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_missing_manifest_entry_is_corrupt(tmp_path):
    import json

    from repro.checkpoint.checkpointer import CheckpointCorruptError

    ck = Checkpointer(tmp_path)
    ck.save(4, _tree(), blocking=True)
    mf = Path(tmp_path) / "step_00000004" / "manifest.json"
    manifest = json.loads(mf.read_text())
    del manifest["leaves"]["nested/b"]
    mf.write_text(json.dumps(manifest))
    with pytest.raises(CheckpointCorruptError, match="step 4") as exc:
        ck.restore(_tree(), step=4)
    assert exc.value.step == 4
    assert "nested/b" in exc.value.reason


def test_explicit_step_raises_instead_of_falling_back(tmp_path):
    """An explicitly requested step must fail loudly (naming the bad step)
    rather than silently loading older state."""
    from repro.checkpoint.checkpointer import CheckpointCorruptError

    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t, blocking=True)
    ck.save(2, t, blocking=True)
    f = Path(tmp_path) / "step_00000002" / "arrays.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(CheckpointCorruptError, match="step 2"):
        ck.restore(t, step=2)
    # ... while the default resume path falls back to step 1
    _, step = ck.restore(t)
    assert step == 1


def test_every_checkpoint_corrupt_aggregates(tmp_path):
    from repro.checkpoint.checkpointer import CheckpointCorruptError

    ck = Checkpointer(tmp_path)
    t = _tree()
    for s in (1, 2):
        ck.save(s, t, blocking=True)
        (Path(tmp_path) / f"step_{s:08d}" / "arrays.npz").write_bytes(b"x")
    with pytest.raises(CheckpointCorruptError, match="every complete"):
        ck.restore(t)


def test_incomplete_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    # simulate a crash mid-write: tmp dir without manifest rename
    crashed = Path(tmp_path) / "step_00000002.tmp"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"garbage")
    assert ck.latest_step() == 1


def test_crash_restart_resumes_identically(tmp_path):
    """End-to-end fault tolerance: a job killed mid-run resumes from the
    checkpoint and reaches the SAME final params as an uninterrupted run
    (deterministic data => identical trajectories)."""
    import repro.train.loop as tl
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, make_pipeline
    from repro.models import lm
    from repro.models.config import reduced_for_smoke
    from repro.optim import adamw
    from repro.sharding import rules
    from repro.train import steps as train_steps

    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32", n_layers=2, d_model=32, d_ff=64,
        vocab_size=128, head_dim=8,
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = train_steps.TrainConfig(use_kernel=False)
    step, _ = train_steps.make_train_step(
        cfg, tcfg, adamw.AdamWConfig(lr=1e-3), mesh, rules.ShardingPolicy()
    )
    jstep = jax.jit(step)
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=2, seed=3))

    def fresh():
        p = lm.init_params(jax.random.PRNGKey(0), cfg)
        return p, adamw.init_state(p)

    # uninterrupted run: 10 steps
    p, o = fresh()
    straight = tl.run(jstep, p, o, data,
                      tl.LoopConfig(total_steps=10, ckpt_every=100,
                                    ckpt_dir=str(tmp_path / "a"), log_every=100))

    # crashing run: dies at step 6, restarts, resumes from step-5 checkpoint
    p, o = fresh()
    with pytest.raises(RuntimeError, match="injected"):
        tl.run(jstep, p, o, data,
               tl.LoopConfig(total_steps=10, ckpt_every=5,
                             ckpt_dir=str(tmp_path / "b"), log_every=100,
                             fail_at_step=6))
    p, o = fresh()   # restart from scratch; loop restores ckpt
    resumed = tl.run(jstep, p, o, data,
                     tl.LoopConfig(total_steps=10, ckpt_every=5,
                                   ckpt_dir=str(tmp_path / "b"), log_every=100))

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_straggler_detection(tmp_path):
    import time

    import repro.train.loop as tl

    calls = {"n": 0}

    def slow_step(p, o, b):
        calls["n"] += 1
        if calls["n"] == 3:
            time.sleep(0.05)
        return p, o, {"loss": jnp.asarray(1.0), "grad_norm": jnp.asarray(0.0),
                      "lr": jnp.asarray(0.0)}

    class Data:
        def batch(self, step):
            return {}

    st = tl.run(slow_step, {}, {}, Data(),
                tl.LoopConfig(total_steps=5, ckpt_every=100, log_every=100,
                              ckpt_dir=str(tmp_path), step_deadline_s=0.03))
    assert any(s == 2 for s, _ in st.slow_steps)
