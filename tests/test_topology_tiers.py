"""N-tier topology API: tier hierarchy, back-compat surface, per-tier cost
features, stage-per-tier calibration, and the JSON v1 -> v2 upgrade path.

The api_redesign invariants:

  * ``ClusterTopology(tiers=, fanout=)`` generalizes the fixed local/global
    pair; the legacy two-tier constructor, ``two_tier``, and the derived
    ``local`` / ``global_`` / ``n_machines`` / ``procs_per_machine``
    properties are exact views of it;
  * ``param_vector()`` / ``fitted_tiers()`` round-trip for arbitrary tier
    counts (property test);
  * ``cost_features`` stays an exact linear decomposition
    (``features @ params == simulate_rounds``) on 3-tier topologies;
  * a 3-tier topology plans, simulates, and calibrates: the synthetic fit
    recovers injected per-tier alpha/beta within 10% relative error;
  * persisted version-1 (two-tier) calibration JSONs load unchanged through
    the upgrade layer.
"""

import json

import numpy as np
import pytest

from repro import comm
from repro.comm.calibrate import (
    CalibrationResult,
    Measurement,
    fit_calibration,
    fit_topology,
    load_calibration,
    save_calibration,
)
from repro.core import schedules as S
from repro.core.simulator import (
    check_semantics,
    cost_features,
    n_cost_features,
    pipelined_cost_features,
    simulate_async,
    simulate_pipelined,
    simulate_rounds,
    validate,
)
from repro.core.topology import (
    TOPOLOGY_PRESETS,
    ClusterTopology,
    LinkTier,
    paper_smp_3tier,
    paper_smp_cluster,
    topology_preset,
    tpu_v5e_3tier,
    tpu_v5e_cluster,
)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # tier-1 env has no hypothesis; CI installs it
    from _hypothesis_compat import given, settings, strategies as st


THREE_TIER = ClusterTopology(
    tiers=(
        LinkTier("shm", alpha=2e-6, beta=1.0 / 1.5e9),
        LinkTier("pcie", alpha=8e-6, beta=1.0 / 8.0e8),
        LinkTier("eth", alpha=40e-6, beta=1.0 / 2.0e8),
    ),
    fanout=(2, 2, 4),
    degree=2,
    write_cost=1.5e-6,
    assemble_cost=0.0,
)

T3_SMALL = paper_smp_3tier(n_machines=3, boards=2, cores=2, nics=2)


# ----------------------------------------------------------------------
# The tier-list API and its two-tier back-compat surface
# ----------------------------------------------------------------------

def test_two_tier_constructions_agree():
    legacy = ClusterTopology(
        n_machines=4, procs_per_machine=8, degree=2,
        local=LinkTier("shm", 1e-6, 1e-9),
        global_=LinkTier("eth", 5e-5, 8e-9),
        write_cost=1e-6, assemble_cost=2e-6,
    )
    one_liner = ClusterTopology.two_tier(
        4, 8, 2, LinkTier("shm", 1e-6, 1e-9), LinkTier("eth", 5e-5, 8e-9),
        1e-6, 2e-6,
    )
    tier_list = ClusterTopology(
        tiers=(LinkTier("shm", 1e-6, 1e-9), LinkTier("eth", 5e-5, 8e-9)),
        fanout=(8, 4), degree=2, write_cost=1e-6, assemble_cost=2e-6,
    )
    assert legacy == one_liner == tier_list
    assert legacy.n_tiers == 2
    assert legacy.local.name == "shm" and legacy.global_.name == "eth"
    assert legacy.n_machines == 4 and legacy.procs_per_machine == 8
    assert legacy.n_procs == 32
    assert hash(legacy) == hash(tier_list)


def test_derived_two_tier_view_of_three_tier():
    t = THREE_TIER
    assert t.n_tiers == 3
    assert t.n_procs == 16
    # machine = outermost group; procs_per_machine = everything inside
    assert t.n_machines == 4 and t.procs_per_machine == 4
    assert t.local is t.tiers[0] and t.global_ is t.tiers[-1]
    assert t.machine_of(5) == 1
    assert t.co_located(4, 7) and not t.co_located(3, 4)


def test_hierarchical_coordinates_and_tier_index():
    t = THREE_TIER  # fanout (2, 2, 4)
    assert t.coords(0) == (0, 0, 0)
    assert t.coords(1) == (1, 0, 0)
    assert t.coords(2) == (0, 1, 0)
    assert t.coords(7) == (1, 1, 1)
    assert t.tier_index(0, 1) == 0      # same board
    assert t.tier_index(0, 2) == 1      # same machine, different board
    assert t.tier_index(0, 4) == 2      # different machine
    assert t.tier(0, 4).name == "eth"
    assert t.inner_group_of(3) == 1
    assert list(t.inner_peers(5)) == [4, 5]
    assert list(t.group_procs(2, 1)) == [4, 5, 6, 7]
    with pytest.raises(ValueError):
        t.tier_index(3, 3)


def test_with_accepts_legacy_and_tier_fields():
    t = THREE_TIER
    assert t.with_(n_machines=2).fanout == (2, 2, 2)
    assert t.with_(degree=1).degree == 1
    fast = LinkTier("fast_eth", 1e-5, 2e-9)
    assert t.with_(global_=fast).tiers[-1] is fast
    # procs_per_machine is only meaningful on two-tier topologies
    with pytest.raises(ValueError):
        t.with_(procs_per_machine=8)
    two = paper_smp_cluster(4, 4, 2)
    assert two.with_(procs_per_machine=8).fanout == (8, 4)
    with pytest.raises(TypeError):
        two.with_(bogus_field=1)


def test_per_tier_degrees_surface():
    """PR-5 Rule-3 generalization: ``degrees`` defaults to unlimited inner
    tiers + ``degree`` outermost, validates its shape, and survives the
    functional-update surface."""
    t = THREE_TIER
    assert t.degrees == (0, 0, t.degree)
    assert t.tier_degree(0) == 0 and t.tier_degree(2) == t.degree
    lim = t.with_(degrees=(0, 2, t.degree))
    assert lim.tier_degree(1) == 2
    # degree updates track the outermost entry (and vice versa)
    assert lim.with_(degree=1).degrees == (0, 2, 1)
    assert t.with_(degrees=(0, 0, 3)).degree == 3
    # truncation keeps the inner entries and re-crowns the outermost
    assert lim.with_shape((2, 2)).degrees == (0, lim.degree)
    with pytest.raises(ValueError, match="degrees"):
        ClusterTopology(
            tiers=t.tiers, fanout=t.fanout, degree=t.degree,
            write_cost=1e-6, degrees=(0, t.degree),
        )
    with pytest.raises(ValueError, match="outermost"):
        ClusterTopology(
            tiers=t.tiers, fanout=t.fanout, degree=2,
            write_cost=1e-6, degrees=(0, 0, 3),
        )


def test_with_shape_and_stage():
    t = THREE_TIER
    assert t.with_shape((4, 8, 2)).fanout == (4, 8, 2)
    truncated = t.with_shape((2, 2))
    assert truncated.n_tiers == 2
    assert truncated.tiers == t.tiers[:2]
    assert t.stage(2).fanout == (2, 2, 1)
    assert t.stage(1).fanout == (2, 1)
    # two-tier stage(1) is the classic single-machine local stage
    two = paper_smp_cluster(4, 4, 2)
    assert two.stage(1) == two.with_(n_machines=1)
    with pytest.raises(ValueError):
        t.stage(3)
    with pytest.raises(ValueError):
        t.with_shape((2, 2, 4, 4))


def test_tier_monotonicity_enforced():
    slow_inner = LinkTier("slow", 1e-3, 1e-6)
    fast_outer = LinkTier("fast", 1e-6, 1e-9)
    with pytest.raises(ValueError):
        ClusterTopology(
            tiers=(slow_inner, fast_outer), fanout=(2, 2), degree=1,
            write_cost=1e-6,
        )
    with pytest.raises(ValueError):
        ClusterTopology(
            tiers=(fast_outer, fast_outer, slow_inner, fast_outer),
            fanout=(2, 2, 2, 2), degree=1, write_cost=1e-6,
        )
    with pytest.raises(ValueError):
        ClusterTopology(
            tiers=(fast_outer,), fanout=(4,), degree=1, write_cost=1e-6
        )
    with pytest.raises(ValueError):
        ClusterTopology(
            tiers=(fast_outer, fast_outer), fanout=(2, 2, 2), degree=1,
            write_cost=1e-6,
        )
    # degree and write_cost stay required, as in the pre-tier-list API
    with pytest.raises(ValueError, match="write_cost is required"):
        ClusterTopology(
            tiers=(fast_outer, fast_outer), fanout=(2, 2), degree=1
        )
    with pytest.raises(ValueError, match="degree is required"):
        ClusterTopology(
            tiers=(fast_outer, fast_outer), fanout=(2, 2), write_cost=1e-6
        )


def test_presets():
    v2 = tpu_v5e_cluster(2)
    v3 = tpu_v5e_3tier(2)
    assert v2.n_procs == v3.n_procs == 512
    assert v3.n_tiers == 3 and v3.fanout == (4, 64, 2)
    assert [t.name for t in v3.tiers] == ["ici", "pcie", "dcn"]
    assert set(TOPOLOGY_PRESETS) >= {"v5e", "v5e_3tier", "smp"}
    assert topology_preset("v5e_3tier", 4).n_machines == 4
    with pytest.raises(ValueError):
        topology_preset("nope", 2)


# ----------------------------------------------------------------------
# param_vector / fitted round-trips (property test, arbitrary tier count)
# ----------------------------------------------------------------------

@given(
    n_tiers=st.integers(2, 5),
    seed=st.integers(0, 7),
)
@settings(max_examples=20, deadline=None)
def test_param_vector_fitted_tiers_round_trip(n_tiers, seed):
    """fitted_tiers(param_vector()) is the identity for any feasible
    parameter vector at any tier count; infeasible vectors project onto
    the feasible region (monotone tiers, positive floors)."""
    rng = np.random.RandomState(seed * 31 + n_tiers)
    fanout = tuple(int(f) for f in rng.randint(1, 5, size=n_tiers))
    alphas = np.sort(rng.uniform(1e-7, 1e-4, size=n_tiers))
    betas = np.sort(rng.uniform(1e-11, 1e-8, size=n_tiers))
    topo = ClusterTopology.fitted_tiers(
        fanout, degree=2, alphas=list(alphas), betas=list(betas),
        write_cost=1e-6, assemble_cost=3e-7,
    )
    vec = topo.param_vector()
    assert len(vec) == 2 * n_tiers + 2
    assert vec == pytest.approx(
        tuple(np.ravel(np.column_stack([alphas, betas]))) + (1e-6, 3e-7)
    )
    # round-trip through fitted_tiers is exact for a feasible vector
    again = ClusterTopology.fitted_tiers(
        fanout, degree=2,
        alphas=[vec[2 * i] for i in range(n_tiers)],
        betas=[vec[2 * i + 1] for i in range(n_tiers)],
        write_cost=vec[-2], assemble_cost=vec[-1],
        names=tuple(t.name for t in topo.tiers),
    )
    assert again == topo
    # infeasible input projects: reversed alphas come back monotone
    proj = ClusterTopology.fitted_tiers(
        fanout, degree=2, alphas=list(alphas[::-1]), betas=list(betas),
        write_cost=-1.0,
    )
    pv = proj.param_vector()
    proj_alphas = [pv[2 * i] for i in range(n_tiers)]
    assert proj_alphas == sorted(proj_alphas)
    assert pv[-2] > 0


@given(n_tiers=st.integers(2, 4), seed=st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_cost_features_width_tracks_tier_count(n_tiers, seed):
    rng = np.random.RandomState(seed * 17 + n_tiers)
    fanout = tuple(int(f) for f in rng.randint(2, 4, size=n_tiers))
    topo = ClusterTopology.fitted_tiers(
        fanout, degree=2,
        alphas=list(np.sort(rng.uniform(1e-6, 1e-4, size=n_tiers))),
        betas=list(np.sort(rng.uniform(1e-10, 1e-8, size=n_tiers))),
        write_cost=1e-6,
    )
    assert n_cost_features(topo) == 2 * n_tiers + 2
    sched = S.allreduce_hier_par_bw(topo, 4096.0, payloads=False)
    feats = cost_features(sched)
    assert len(feats) == 2 * n_tiers + 2
    t_lin = float(np.dot(feats, topo.param_vector()))
    assert t_lin == pytest.approx(simulate_rounds(sched, check=False),
                                  rel=1e-12)


# ----------------------------------------------------------------------
# 3-tier planning + simulation
# ----------------------------------------------------------------------

def test_every_registered_strategy_plans_on_three_tier():
    """Acceptance: a 3-tier ClusterTopology plans and simulates every
    registry strategy (the registry's import-time smoke re-checked on a
    larger instance, with semantics for the lossless ones)."""
    for topo in (T3_SMALL, THREE_TIER):
        for spec in comm.specs():
            if not spec.supports(topo):
                continue
            sched = spec.build_schedule(topo, 2048.0, payloads=True)
            validate(sched)
            if not spec.lossy:
                check_semantics(sched)
            assert simulate_rounds(sched, check=False) > 0
            assert simulate_async(sched, check=False) > 0


def test_cost_features_exact_on_three_tier():
    """The satellite acceptance: features @ params == simulate_rounds on
    3-tier topologies, for every registered strategy and both payload
    modes."""
    for topo in (T3_SMALL, THREE_TIER):
        for spec in comm.specs():
            if not spec.supports(topo):
                continue
            for m in (1024.0, 65536.0):
                sched = spec.build_schedule(topo, m, payloads=False)
                t_lin = float(
                    np.dot(cost_features(sched), topo.param_vector())
                )
                t_sim = simulate_rounds(sched, check=False)
                assert t_lin == pytest.approx(t_sim, rel=1e-12), (
                    spec.collective, spec.strategy, m,
                )


def test_pipelined_cost_features_exact_on_three_tier():
    topo = T3_SMALL
    for coll, strat in [
        ("all_reduce", "hier_par_bw"),
        ("reduce_scatter", "hier_par"),
        ("all_gather", "hier_par"),
    ]:
        spec = comm.get_spec(coll, strat)
        build = lambda m: spec.build_schedule(topo, m, payloads=False)
        for n in (1, 3, 8):
            f = pipelined_cost_features(build, 2e5, n)
            assert len(f) == n_cost_features(topo)
            t_lin = float(np.dot(f, topo.param_vector()))
            want = simulate_pipelined(build, 2e5, n, check=False).t_pipelined
            assert t_lin == pytest.approx(want, rel=1e-12), (coll, strat, n)


def test_three_tier_rankings_can_flip_per_level():
    """The motivation (Barchet-Estefanel & Mounie): with a third tier the
    model exposes crossovers a two-tier collapse cannot express -- the
    tier-recursive schedules pay the mid tier explicitly."""
    t3 = tpu_v5e_3tier(2)
    t2 = tpu_v5e_cluster(2)
    for m in (1e4, 1e8):
        ranking3 = [p.strategy for p in comm.enumerate_plans(
            t3, "all_reduce", m, executable_only=True)]
        ranking2 = [p.strategy for p in comm.enumerate_plans(
            t2, "all_reduce", m, executable_only=True)]
        assert set(ranking3) == set(ranking2)
    # mid-tier hops make the 3-tier model strictly more expensive than the
    # 2-tier collapse for the same hierarchical schedule (ICI-only is the
    # old model's fiction)
    bw3 = comm.plan_for_spec(t3, comm.get_spec("all_reduce", "hier_par_bw"), 1e8)
    bw2 = comm.plan_for_spec(t2, comm.get_spec("all_reduce", "hier_par_bw"), 1e8)
    assert bw3.t_rounds > bw2.t_rounds


def test_schedule_local_writes_stay_in_shared_memory_groups():
    """Rule 1 generalized: LocalWrites never cross a tier-0 group on any
    hierarchy depth (validate enforces it; generators must comply)."""
    topo = T3_SMALL
    for spec in comm.specs():
        if not spec.supports(topo):
            continue
        sched = spec.build_schedule(topo, 1024.0, payloads=False)
        for op in sched.all_ops():
            if isinstance(op, S.LocalWrite):
                for r in op.readers:
                    assert topo.inner_group_of(op.writer) == \
                        topo.inner_group_of(r)


# ----------------------------------------------------------------------
# 3-tier calibration: synthetic round trip + JSON versioning
# ----------------------------------------------------------------------

SIZES = [256.0, 1024.0, 4096.0, 16384.0, 65536.0, 262144.0, 1048576.0]


def synthetic_measurements_3tier(noise=0.02, seed=0):
    """Timings generated by the round model itself on a hidden 3-tier
    topology, from the full shape AND every truncated tier stage (the
    stage-per-tier sweep ``probe_collectives`` runs)."""
    rng = np.random.RandomState(seed)
    out = []
    stages = [THREE_TIER, THREE_TIER.stage(2), THREE_TIER.stage(1)]
    for topo in stages:
        shape = (topo.n_machines, topo.procs_per_machine, topo.degree)
        for coll, strat in comm.executable_pairs():
            spec = comm.get_spec(coll, strat)
            if spec.lossy or not spec.supports(topo):
                continue
            roots = (
                sorted({0, topo.n_procs - 1})
                if spec.caps.needs_root and topo.n_procs > 1
                else [0]
            )
            for root in roots:
                for m in SIZES:
                    t = simulate_rounds(
                        spec.build_schedule(topo, m, root=root,
                                            payloads=False),
                        check=False,
                    )
                    t *= 1 + noise * rng.randn()
                    out.append(
                        Measurement(coll, strat, m, t, root=root,
                                    shape=shape, fanout=topo.fanout)
                    )
    return out


def test_three_tier_fit_recovers_injected_parameters_within_10pct():
    """The acceptance-criteria round trip: known 3-tier topology -> noisy
    timings -> stage-per-tier fit -> per-tier alpha/beta within 10%."""
    ms = synthetic_measurements_3tier(noise=0.02, seed=0)
    fit = fit_topology(ms, degree=THREE_TIER.degree,
                       fanout=THREE_TIER.fanout)
    got = fit.topology.param_vector()
    want = THREE_TIER.param_vector()
    labels = [
        "alpha_shm", "beta_shm", "alpha_pcie", "beta_pcie",
        "alpha_eth", "beta_eth", "write_cost",
    ]
    for name, a, b in zip(labels, want, got):
        assert abs(b - a) / a < 0.10, (name, a, b)
    assert fit.rel_rmse < 0.10
    assert fit.n_measurements == len(ms)


def test_three_tier_calibration_json_round_trip(tmp_path):
    ms = synthetic_measurements_3tier(noise=0.01, seed=1)
    calib = fit_calibration(ms, THREE_TIER, meta={"source": "synthetic3"})
    p = tmp_path / "calibration3.json"
    save_calibration(calib, p)
    raw = json.loads(p.read_text())
    assert raw["version"] == 2
    assert len(raw["topology"]["tiers"]) == 3
    assert raw["topology"]["fanout"] == [2, 2, 4]
    back = load_calibration(p)
    assert back.topology == calib.topology
    assert back.measurements == calib.measurements
    assert back.measurements[0].fanout is not None
    # context plumbing: validate against evidence incl. stage probes
    ctx = comm.CommContext.from_calibration(str(p))
    rows = ctx.validate_against_measurements(calib.measurements)
    assert np.mean([abs(r["rel_error"]) for r in rows]) < 0.10
    # transplant onto the production 3-tier shape
    big = comm.CommContext.from_calibration(str(p), fanout=(4, 64, 2))
    assert big.topo.fanout == (4, 64, 2)
    assert big.topo.tiers[1].alpha == ctx.topo.tiers[1].alpha


def test_v1_calibration_files_upgrade_transparently(tmp_path):
    """Satellite: the loader upgrades persisted version-1 (fixed
    local/global pair) files -- old calibrations keep working unchanged."""
    v1 = dict(
        version=1,
        topology=dict(
            n_machines=4, procs_per_machine=4, degree=2,
            local=dict(name="local_fit", alpha=2e-6, beta=6.7e-10),
            global_=dict(name="global_fit", alpha=4e-5, beta=5e-9),
            write_cost=1.5e-6, assemble_cost=0.0,
        ),
        fit=dict(rel_rmse=0.03, n_iterations=4),
        meta=dict(source="pr2-era"),
        measurements=[
            dict(collective="all_reduce", strategy="hier_par", nbytes=1024.0,
                 t_measured=1e-4, t_modelled=1.1e-4, root=0,
                 shape=[4, 4, 2]),
        ],
    )
    p = tmp_path / "old.json"
    p.write_text(json.dumps(v1))
    calib = load_calibration(p)
    assert calib.topology.n_tiers == 2
    assert calib.topology.fanout == (4, 4)
    assert calib.topology.local.alpha == pytest.approx(2e-6)
    assert calib.topology.global_.beta == pytest.approx(5e-9)
    assert calib.meta["source"] == "pr2-era"
    assert calib.measurements[0].shape == (4, 4, 2)
    assert calib.measurements[0].fanout is None
    # and it plans through the context like any fresh calibration
    ctx = comm.CommContext.from_calibration(calib, n_machines=8)
    assert ctx.topo.n_machines == 8
    assert ctx.plan("all_reduce", 1e6).executable
    # unknown future versions still refuse loudly
    p2 = tmp_path / "future.json"
    p2.write_text(json.dumps(dict(v1, version=99)))
    with pytest.raises(ValueError, match="unsupported calibration version"):
        load_calibration(p2)


def test_rooted_probes_cached_and_costed_per_root():
    """Satellite (rooted calibration): per-root plans differ when root
    placement changes egress serialization, and the affine cache keys on
    the root."""
    topo = paper_smp_cluster(n_machines=3, cores=4, nics=2)
    spec = comm.get_spec("broadcast", "hier_par")
    p0 = comm.plan_for_spec(topo, spec, 4096.0, root=0)
    p_far = comm.plan_for_spec(topo, spec, 4096.0, root=topo.n_procs - 1)
    assert p0.root == 0 and p_far.root == topo.n_procs - 1
    # same cost model, different roots: both plan, times positive
    assert p0.t_rounds > 0 and p_far.t_rounds > 0
    # gather's asymmetric ingress makes root placement visible in rounds
    ga = comm.get_spec("gather", "hier_par")
    g0 = ga.build_schedule(topo, 4096.0, root=0, payloads=False)
    g_far = ga.build_schedule(topo, 4096.0, root=topo.n_procs - 1,
                              payloads=False)
    assert g0.n_rounds == g_far.n_rounds  # symmetric shape, shifted root


def test_pod_sync_plans_on_three_tier_preset():
    """plan_pod_sync accepts the 3-tier preset by name and returns a
    runnable decision (the --topology wiring)."""
    d2 = comm.plan_pod_sync(2, 4e9, topology="v5e")
    d3 = comm.plan_pod_sync(2, 4e9, topology="v5e_3tier")
    for d in (d2, d3):
        assert d.fmt in comm.POD_SYNC_FORMATS
        assert d.t_modelled <= d.t_monolithic
    topo3 = comm.pod_sync_topology(2, topology="v5e_3tier")
    assert topo3.n_tiers == 3 and topo3.n_machines == 2
    assert comm.select_pod_sync(2, 1e8, topology="v5e_3tier") in \
        comm.POD_SYNC_FORMATS


def test_pod_sync_topology_tier_mismatch_falls_back(tmp_path):
    """A two-tier calibration consumed under the 3-tier preset plans on
    the calibrated hierarchy (with a warning) instead of crashing."""
    two = ClusterTopology.fitted(
        2, 4, 2, alpha_local=1e-6, beta_local=1e-9,
        alpha_global=2e-5, beta_global=4e-9, write_cost=1e-6,
    )
    calib = CalibrationResult(
        topology=two, measurements=(), rel_rmse=0.0, n_iterations=1,
    )
    p = tmp_path / "two.json"
    save_calibration(calib, p)
    with pytest.warns(RuntimeWarning, match="fitted 2 tiers"):
        topo = comm.pod_sync_topology(4, calibration=str(p),
                                      topology="v5e_3tier")
    assert topo.n_tiers == 2 and topo.n_machines == 4
    # matching tier counts transplant exactly
    topo2 = comm.pod_sync_topology(4, calibration=str(p), topology="v5e")
    assert topo2.n_tiers == 2
    assert topo2.fanout == (256, 4)
    assert topo2.local.alpha == two.local.alpha
