"""q8 codec edge cases: odd trailing dims, zero blocks, bf16, error bounds.

Property-style round-trip tests via the hypothesis shim (tier-1 env runs a
deterministic boundary sweep; CI runs real hypothesis).  The codec contract
being pinned: blockwise symmetric int8 quantization over the last axis has
per-element absolute error <= max|block| / 127 (half an int8 step, doubled
for slack), exactly-zero blocks decode to exactly zero, and trailing dims
that don't divide ``Q8_BLOCK`` round-trip without corrupting shape.
"""

import numpy as np
import pytest

from repro.comm import Q8_BLOCK, q8_decode, q8_decode_sum, q8_encode

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # tier-1 env has no hypothesis; CI installs it
    from _hypothesis_compat import given, settings, strategies as st


def _roundtrip(x):
    import jax.numpy as jnp

    xj = jnp.asarray(x)
    q, s, last = q8_encode(xj)
    assert last == x.shape[-1]
    assert q.dtype == jnp.int8
    assert q.shape[-1] == Q8_BLOCK
    y = q8_decode(q, s, last, xj.shape, xj.dtype)
    assert y.shape == xj.shape and y.dtype == xj.dtype
    return np.asarray(q), np.asarray(s), np.asarray(y, dtype=np.float64)


@given(
    last=st.integers(1, 2 * Q8_BLOCK + 3),
    lead=st.sampled_from([(), (3,), (2, 5)]),
)
@settings(max_examples=24, deadline=None)
def test_roundtrip_bound_odd_trailing_dims(last, lead):
    """Trailing dims not divisible by Q8_BLOCK: shape survives and the
    per-block error bound holds on the real (unpadded) elements."""
    rng = np.random.RandomState(last * 31 + len(lead))
    x = (rng.randn(*lead, last) * 10).astype(np.float32)
    _, _, y = _roundtrip(x)
    # per-block bound: |x - y| <= max|block| / 127 (rounding is half a
    # step; factor 2 slack for the f32 scale itself being rounded)
    flat_x = x.reshape(-1, last)
    flat_y = y.reshape(-1, last)
    for row_x, row_y in zip(flat_x, flat_y):
        for lo in range(0, last, Q8_BLOCK):
            blk = row_x[lo:lo + Q8_BLOCK]
            bound = np.abs(blk).max() / 127.0 + 1e-12
            assert np.abs(blk - row_y[lo:lo + Q8_BLOCK]).max() <= bound


def test_zero_blocks_decode_to_exact_zero():
    """All-zero blocks hit the scale==0 guard: scale forced to 1, q == 0,
    decode returns exact zeros (no NaNs from 0/0)."""
    x = np.zeros((3, 130), np.float32)
    q, s, y = _roundtrip(x)
    assert np.all(q == 0)
    assert np.all(s == 1.0)
    assert np.all(y == 0.0)
    # mixed: one zero block among live ones stays exactly zero
    x = np.zeros((Q8_BLOCK * 3,), np.float32)
    x[:Q8_BLOCK] = 7.5
    x[2 * Q8_BLOCK:] = -3.25
    _, _, y = _roundtrip(x)
    assert np.all(y[Q8_BLOCK:2 * Q8_BLOCK] == 0.0)
    assert not np.isnan(y).any()


@given(last=st.integers(1, 200))
@settings(max_examples=16, deadline=None)
def test_bf16_roundtrip(last):
    """bf16 inputs: decode returns bf16 of the right shape, error within
    the combined q8 + bf16 resolution."""
    import jax.numpy as jnp

    rng = np.random.RandomState(last)
    x32 = (rng.randn(4, last) * 5).astype(np.float32)
    x = jnp.asarray(x32).astype(jnp.bfloat16)
    q, s, lastq = q8_encode(x)
    assert s.dtype == jnp.float32  # scales stay f32 even for bf16 payloads
    y = q8_decode(q, s, lastq, x.shape, x.dtype)
    assert y.dtype == jnp.bfloat16 and y.shape == x.shape
    xf = np.asarray(x, dtype=np.float32)
    yf = np.asarray(y, dtype=np.float32)
    scale = np.abs(xf).max() + 1e-9
    # 1/127 quantization + ~1/128 bf16 mantissa, generous slack
    assert np.abs(xf - yf).max() / scale < 0.03


def test_decode_sum_matches_sum_of_decodes():
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    xs = [(rng.randn(97) * 3).astype(np.float32) for _ in range(4)]
    qs, ss = [], []
    for x in xs:
        q, s, last = q8_encode(jnp.asarray(x))
        qs.append(q)
        ss.append(s)
    got = np.asarray(
        q8_decode_sum(
            jnp.stack(qs), jnp.stack(ss), 97, (97,), jnp.float32,
            scale=0.25,
        )
    )
    want = np.mean(
        [
            np.asarray(q8_decode(q, s, 97, (97,), jnp.float32))
            for q, s in zip(qs, ss)
        ],
        axis=0,
    )
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_encode_rejects_nothing_but_preserves_large_tensors_shape():
    """Leading dims are never flattened (the >2^31-element contract): the
    block structure only reshapes the last axis."""
    import jax.numpy as jnp

    x = jnp.ones((5, 7, Q8_BLOCK * 2 + 1), jnp.float32)
    q, s, last = q8_encode(x)
    assert q.shape == (5, 7, 3, Q8_BLOCK)
    assert s.shape == (5, 7, 3, 1)
    assert last == Q8_BLOCK * 2 + 1


@pytest.mark.parametrize("shape", [(64,), (100,), (3, 7, 11)])
def test_roundtrip_relative_error_small(shape):
    import jax.numpy as jnp

    rng = np.random.RandomState(42)
    x = jnp.asarray(rng.randn(*shape).astype(np.float32) * 10)
    q, s, last = q8_encode(x)
    y = q8_decode(q, s, last, x.shape, x.dtype)
    err = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
    assert err < 1e-2, (shape, err)
