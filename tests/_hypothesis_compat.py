"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 environment does not ship hypothesis; rather than skip the
property tests entirely (or crash collection, as the seed did), this
module degrades ``@given`` to a fixed sweep over each strategy's boundary
and midpoint samples.  With hypothesis available the real library is used
(see the try/except at the importers).
"""

from __future__ import annotations

import itertools

_MAX_COMBOS = 24


class _Strategy:
    def __init__(self, samples):
        self.samples = list(samples)


class strategies:  # noqa: N801  (mirrors `hypothesis.strategies` usage)
    @staticmethod
    def integers(min_value, max_value):
        mid = (min_value + max_value) // 2
        vals = sorted({min_value, mid, max_value})
        return _Strategy(vals)

    @staticmethod
    def floats(min_value, max_value):
        mid = (min_value + max_value) / 2.0
        return _Strategy([min_value, mid, max_value])

    @staticmethod
    def sampled_from(options):
        return _Strategy(list(options))


def given(*sargs, **skwargs):
    """Run the wrapped test over a bounded cartesian sweep of samples."""

    def deco(fn):
        if skwargs:
            names = list(skwargs)
            pools = [skwargs[n].samples for n in names]
        else:
            names = None
            pools = [s.samples for s in sargs]
        total = 1
        for p in pools:
            total *= len(p)
        if total <= _MAX_COMBOS:
            combos = list(itertools.product(*pools))
        else:
            # Evenly spaced mixed-radix sample of the full product, so every
            # pool's boundary/mid values appear (a plain islice would pin the
            # leading pools to their first sample).
            combos = []
            for i in range(_MAX_COMBOS):
                idx = (i * total) // _MAX_COMBOS
                combo = []
                for p in reversed(pools):
                    idx, r = divmod(idx, len(p))
                    combo.append(p[r])
                combos.append(tuple(reversed(combo)))

        # NOTE: no functools.wraps -- pytest must see a zero-arg signature,
        # not the sample parameters (it would hunt for fixtures named after
        # them).
        def wrapper():
            for combo in combos:
                if names is not None:
                    fn(**dict(zip(names, combo)))
                else:
                    fn(*combo)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def settings(**_kw):
    def deco(fn):
        return fn

    return deco
