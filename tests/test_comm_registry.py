"""The registry-based collectives API: one source of truth, validated.

Covers the api_redesign invariants:

  * the registry is consistent (validated at import of ``repro.comm``);
  * every ``Plan.impl`` the planner can emit resolves to a callable (the
    regression for the seed's dangling ``hier_seq`` impl tag);
  * the legacy dicts (``schedules.GENERATORS``, ``MANUAL_ALL_REDUCE``) are
    derived views of the registry, not independent state;
  * ``CommContext.plan`` only returns runnable plans by default, and
    model-only plans refuse to execute.
"""

import pytest

from repro import comm
from repro.core import collectives as legacy_coll
from repro.core import schedules as S
from repro.core.planner import best_plan, enumerate_plans, make_policy
from repro.core.topology import paper_smp_cluster, tpu_v5e_cluster

TOPOS = [
    paper_smp_cluster(n_machines=4, cores=4, nics=2),
    paper_smp_cluster(n_machines=2, cores=8, nics=4),
    tpu_v5e_cluster(n_pods=2),
]


def test_registry_validates_at_import():
    # repro.comm ran validate_registry() on import; re-run explicitly.
    comm.validate_registry()
    assert set(comm.collectives()) == {
        "broadcast", "gather", "all_gather", "all_reduce", "all_to_all",
        "reduce_scatter",
    }


def test_every_plannable_strategy_executable_or_model_only():
    for sp in comm.specs():
        assert sp.executable or sp.model_only, (sp.collective, sp.strategy)
        if sp.executable:
            assert callable(sp.impl) and sp.impl_tag
        else:
            assert sp.impl_tag is None


@pytest.mark.parametrize("topo", TOPOS, ids=["smp4x4", "smp2x8", "tpu2pod"])
@pytest.mark.parametrize(
    "coll",
    ["broadcast", "gather", "all_gather", "all_reduce", "all_to_all",
     "reduce_scatter"],
)
def test_every_emitted_plan_impl_resolves(topo, coll):
    """Regression for the seed bug: ``_IMPL_OF_STRATEGY`` mapped 'hier_seq'
    to an impl tag with no runnable implementation.  Now every plan either
    resolves to a callable or is explicitly marked model-only."""
    for plan in enumerate_plans(topo, coll, 1e6, lossy_ok=True):
        if plan.model_only:
            assert plan.impl is None
        else:
            fn = comm.resolve_impl(coll, plan.impl)
            assert callable(fn), (coll, plan.strategy, plan.impl)


def test_unknown_impl_tag_rejected():
    with pytest.raises(comm.RegistryError):
        comm.resolve_impl("all_reduce", "hier_seq")
    with pytest.raises(comm.RegistryError):
        comm.get_spec("all_reduce", "definitely_not_registered")


def test_impl_less_spec_requires_model_only_marker():
    with pytest.raises(comm.RegistryError):
        comm.CollectiveSpec(
            collective="broadcast", strategy="oops",
            schedule=S.bcast_flat_binomial,
        )
    with pytest.raises(comm.RegistryError):
        comm.CollectiveSpec(
            collective="broadcast", strategy="oops",
            schedule=S.bcast_flat_binomial, impl=lambda x: x,
            impl_tag="oops", model_only=True,
        )


def test_duplicate_registration_rejected():
    with pytest.raises(comm.RegistryError):
        comm.register_model_only(
            "broadcast", "hier_seq", schedule=S.bcast_hier_seq,
        )


def test_legacy_dicts_are_derived_views():
    gens = S.GENERATORS
    view = comm.generators_view()
    assert gens == view
    # seed contents preserved exactly (lossless strategies)
    assert set(gens) == set(comm.collectives())
    assert set(gens["all_reduce"]) == {"flat", "hier_par", "hier_par_bw"}
    assert set(gens["broadcast"]) == {"flat", "hier_seq", "hier_par"}
    # MANUAL_ALL_REDUCE: impl tag -> callable, straight from the registry
    mar = legacy_coll.MANUAL_ALL_REDUCE
    assert mar == comm.executable_view("all_reduce")
    assert set(mar) == {"flat", "hier", "hier_bw", "hier_q8", "hier_bw_q8"}
    assert all(callable(f) for f in mar.values())


def test_schedules_build_round_trips_through_registry():
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    for coll, strats in S.GENERATORS.items():
        for strat in strats:
            sched = S.build(topo, coll, strat, 2048.0, payloads=False)
            assert sched.collective == coll
            assert sched.nbytes == 2048.0


def test_comm_context_plan_is_executable_by_default():
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    for coll in ["broadcast", "all_gather", "all_reduce", "all_to_all",
                 "reduce_scatter"]:
        pc = ctx.plan(coll, 1e6, lossy_ok=(coll == "all_reduce"))
        assert pc.executable
        assert callable(pc.spec.impl)
        assert pc.plan.impl == pc.spec.impl_tag
        assert "rounds" in pc.describe()
    # gather has no runnable impl yet: executable planning must refuse
    # loudly rather than emit a dangling tag ...
    with pytest.raises(comm.RegistryError):
        ctx.plan("gather", 1e6)
    # ... while model-level planning still works for analysis
    pcs = ctx.plans("gather", 1e6)
    assert pcs and all(p.plan.model_only for p in pcs)
    with pytest.raises(comm.ModelOnlyStrategyError):
        pcs[0](None)


def test_lossy_needs_opt_in():
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=8))
    strict = ctx.plans("all_reduce", 4e9, lossy_ok=False)
    assert not any(p.plan.lossy for p in strict)
    loose = ctx.plans("all_reduce", 4e9, lossy_ok=True)
    assert any(p.plan.lossy for p in loose)
    assert loose[0].plan.t_rounds <= strict[0].plan.t_rounds


def test_cost_table_covers_all_strategies():
    ctx = comm.CommContext(paper_smp_cluster(n_machines=4, cores=4, nics=2))
    rows = ctx.cost_table("all_reduce", 1e6)
    ts = [r["t_us"] for r in rows]
    assert ts == sorted(ts)  # best-first
    assert {r["strategy"] for r in rows} >= {
        "flat", "hier_par", "hier_par_bw", "hier_par_bw_q8"
    }
    assert all(r["executable"] for r in rows)  # all_reduce is fully runnable
    bc = ctx.cost_table("broadcast", 1e6)
    assert any(not r["executable"] for r in bc)  # hier_seq is model-only


def test_planner_shims_still_work():
    topo = tpu_v5e_cluster(n_pods=2)
    pol = make_policy(topo, grad_bytes=1e9, moe_bytes=1e6, lossy_grad_ok=True)
    assert pol.grad_sync.collective == "all_reduce"
    assert pol.grad_sync_impl == pol.grad_sync.impl
    assert pol.moe_all_to_all.collective == "all_to_all"
    assert best_plan(topo, "all_reduce", 1e9).strategy in {
        "hier_par", "hier_par_bw"
    }


def test_reduce_scatter_registered_for_all_four_families():
    """The perf-opt acceptance: reduce_scatter exists for flat / hier_par
    and both q8 variants, all executable, with planner/runtime parity
    (validated at import; re-asserted here)."""
    strats = {sp.strategy: sp for sp in comm.specs("reduce_scatter")}
    assert set(strats) == {"flat", "hier_par", "flat_q8", "hier_par_q8"}
    for sp in strats.values():
        assert sp.executable and callable(sp.impl), sp.strategy
        assert sp.lossy == sp.strategy.endswith("_q8")
    topo = tpu_v5e_cluster(n_pods=2)
    pc = comm.CommContext(topo).plan("reduce_scatter", 1e9, lossy_ok=True)
    assert pc.executable
    # a reduce-scatter moves ~half the global bytes of the same-strategy
    # all-reduce (the claim the rs wire formats are built on)
    ar = comm.plan_for_spec(
        topo, comm.get_spec("all_reduce", "hier_par_bw"), 1e9
    )
    rs = comm.plan_for_spec(
        topo, comm.get_spec("reduce_scatter", "hier_par"), 1e9
    )
    assert rs.global_bytes == pytest.approx(ar.global_bytes / 2, rel=1e-6)


def test_select_pod_sync_shapes():
    assert comm.select_pod_sync(1, 1e9) == "flat"
    choice = comm.select_pod_sync(2, 4e9, lossy_ok=True)
    assert choice in comm.POD_SYNC_FORMATS
    lossless = comm.select_pod_sync(2, 4e9, lossy_ok=False)
    assert lossless in ("flat", "rs")


def test_plan_pod_sync_buckets_and_formats():
    """The pipelined planner returns a runnable format and a bucket size
    chosen from alpha/beta -- and bucketing never models slower than
    monolithic for the same format."""
    d = comm.plan_pod_sync(2, 4e9, lossy_ok=True)
    assert d.fmt in comm.POD_SYNC_FORMATS
    assert d.lossy == (d.fmt in comm.LOSSY_POD_SYNC_FORMATS)
    assert d.t_modelled <= d.t_monolithic
    if d.n_chunks > 1:
        assert d.bucket_bytes > 0
        assert d.t_modelled < d.t_monolithic
    # n_pods=1 short-circuits
    d1 = comm.plan_pod_sync(1, 4e9)
    assert d1.fmt == "flat" and not d1.bucketed
    # lossless never returns a q8 format
    d2 = comm.plan_pod_sync(4, 4e9, lossy_ok=False)
    assert d2.fmt in ("flat", "rs")
