"""Training-step semantics: CE correctness, accumulation equivalence,
optimizer behaviour, loss goes down."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import lm
from repro.models.config import reduced_for_smoke
from repro.optim import adamw
from repro.sharding import rules
from repro.train import steps as train_steps

KEY = jax.random.PRNGKey(0)


def _setup(arch="llama3_2_1b", **tkw):
    cfg = reduced_for_smoke(get_config(arch)).with_(compute_dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = train_steps.TrainConfig(use_kernel=False, **tkw)
    step, _ = train_steps.make_train_step(
        cfg, tcfg, adamw.AdamWConfig(lr=1e-3), mesh, rules.ShardingPolicy()
    )
    params = lm.init_params(KEY, cfg)
    opt = adamw.init_state(params)
    return cfg, step, params, opt


def test_cross_entropy_matches_naive():
    logits = jnp.asarray(np.random.RandomState(0).randn(2, 5, 11), jnp.float32)
    labels = jnp.asarray(np.random.RandomState(1).randint(0, 11, (2, 5)))
    got = train_steps.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(p, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_grad_accumulation_equivalent():
    """accum=2 must produce the same update as accum=1 on the same batch."""
    cfg, step1, params, opt = _setup(accum_steps=1)
    _, step2, _, _ = _setup(accum_steps=2)
    tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    p1, o1, m1 = jax.jit(step1)(params, opt, batch)
    p2, o2, m2 = jax.jit(step2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_loss_decreases_over_steps():
    cfg, step, params, opt = _setup()
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4, seed=7))
    jstep = jax.jit(step)
    losses = []
    for i in range(20):
        b = data.batch(0)   # same batch: should overfit fast
        params, opt, m = jstep(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::5]


def test_grad_clip_caps_update_norm():
    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    got = adamw.global_norm(clipped)
    assert float(norm) > 1.0
    np.testing.assert_allclose(float(got), 1.0, rtol=1e-5)


def test_schedule_warmup_and_decay():
    ocfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_frac=0.1)
    assert float(adamw.schedule(ocfg, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(ocfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(ocfg, jnp.asarray(100))) == pytest.approx(0.1)
    assert float(adamw.schedule(ocfg, jnp.asarray(55))) < 1.0


def test_weight_decay_pulls_towards_zero():
    params = {"w": jnp.full((4,), 10.0)}
    grads = {"w": jnp.zeros((4,))}
    st = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9)
    p2, _, _ = adamw.apply_updates(params, grads, st, ocfg)
    assert float(jnp.max(p2["w"])) < 10.0
