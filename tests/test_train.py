"""Training-step semantics: CE correctness, accumulation equivalence,
optimizer behaviour, loss goes down."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import lm
from repro.models.config import reduced_for_smoke
from repro.optim import adamw
from repro.sharding import rules
from repro.train import steps as train_steps

KEY = jax.random.PRNGKey(0)


def _setup(arch="llama3_2_1b", **tkw):
    cfg = reduced_for_smoke(get_config(arch)).with_(compute_dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = train_steps.TrainConfig(use_kernel=False, **tkw)
    step, _ = train_steps.make_train_step(
        cfg, tcfg, adamw.AdamWConfig(lr=1e-3), mesh, rules.ShardingPolicy()
    )
    params = lm.init_params(KEY, cfg)
    opt = adamw.init_state(params)
    return cfg, step, params, opt


def test_cross_entropy_matches_naive():
    logits = jnp.asarray(np.random.RandomState(0).randn(2, 5, 11), jnp.float32)
    labels = jnp.asarray(np.random.RandomState(1).randint(0, 11, (2, 5)))
    got = train_steps.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.mean(jnp.take_along_axis(p, labels[..., None], -1))
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_grad_accumulation_equivalent():
    """accum=2 must produce the same update as accum=1 on the same batch."""
    cfg, step1, params, opt = _setup(accum_steps=1)
    _, step2, _, _ = _setup(accum_steps=2)
    tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    p1, o1, m1 = jax.jit(step1)(params, opt, batch)
    p2, o2, m2 = jax.jit(step2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_loss_decreases_over_steps():
    cfg, step, params, opt = _setup()
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4, seed=7))
    jstep = jax.jit(step)
    losses = []
    for i in range(20):
        b = data.batch(0)   # same batch: should overfit fast
        params, opt, m = jstep(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::5]


def test_grad_clip_caps_update_norm():
    g = {"a": jnp.full((10,), 100.0), "b": jnp.full((5,), -100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    got = adamw.global_norm(clipped)
    assert float(norm) > 1.0
    np.testing.assert_allclose(float(got), 1.0, rtol=1e-5)


def test_schedule_warmup_and_decay():
    ocfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_frac=0.1)
    assert float(adamw.schedule(ocfg, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(ocfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(ocfg, jnp.asarray(100))) == pytest.approx(0.1)
    assert float(adamw.schedule(ocfg, jnp.asarray(55))) < 1.0


def test_weight_decay_pulls_towards_zero():
    params = {"w": jnp.full((4,), 10.0)}
    grads = {"w": jnp.zeros((4,))}
    st = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1e9)
    p2, _, _ = adamw.apply_updates(params, grads, st, ocfg)
    assert float(jnp.max(p2["w"])) < 10.0


# ----------------------------------------------------------------------
# Compute/comm overlap: config plumbing, planner, per-bucket optimizer
# ----------------------------------------------------------------------

def test_parse_overlap_and_estimate():
    assert train_steps.parse_overlap("off") == "off"
    assert train_steps.parse_overlap("auto") == "auto"
    assert train_steps.parse_overlap("8") == 8
    assert train_steps.parse_overlap(4) == 4
    with pytest.raises(ValueError, match="overlap"):
        train_steps.parse_overlap("maybe")
    cfg = reduced_for_smoke(get_config("llama3_2_1b"))
    t = train_steps.estimate_compute_time(cfg, tokens_per_pod=8 * 256)
    assert t > 0
    # linear in tokens
    assert train_steps.estimate_compute_time(
        cfg, tokens_per_pod=2 * 8 * 256
    ) == pytest.approx(2 * t)


def test_plan_pod_sync_overlap_auto_never_worse_than_serial():
    """Acceptance: with overlap='auto' the planner's modelled STEP time is
    <= the serial plan's, on calibrated 2- and 3-tier topologies."""
    from repro import comm
    from repro.core.topology import ClusterTopology

    fitted2 = ClusterTopology.fitted_tiers(
        (8, 4), degree=4, alphas=(1.1e-6, 9.7e-6),
        betas=(2.1e-11, 4.3e-11), write_cost=1.2e-6, assemble_cost=0.9e-6,
    )
    fitted3 = ClusterTopology.fitted_tiers(
        (2, 4, 4), degree=4, alphas=(1.1e-6, 3.2e-6, 9.7e-6),
        betas=(2.1e-11, 3.3e-11, 4.3e-11), write_cost=1.2e-6,
        assemble_cost=0.9e-6,
    )
    for topo in (fitted2, fitted3):
        for c in (0.0, 0.005, 0.5):
            serial = comm.plan_pod_sync(
                4, 4e9, topo=topo, compute_time=c, accum_steps=8,
                overlap="off",
            )
            auto = comm.plan_pod_sync(
                4, 4e9, topo=topo, compute_time=c, accum_steps=8,
                overlap="auto",
            )
            assert auto.t_step <= serial.t_step + 1e-15, (
                topo.n_tiers, c, auto, serial)
        # a big enough compute shadow makes the overlapped step win
        # strictly, with positive depth and a sub-serial exposed tail
        # (dispatch_cost pinned to 0: this asserts the overlap mechanics,
        # not the committed BENCH_step fixture's fitted issue overhead,
        # which on CPU fake devices is large enough to flip the choice)
        big = comm.plan_pod_sync(
            4, 4e9, topo=topo, compute_time=2.0, accum_steps=8,
            overlap="auto", dispatch_cost=0.0,
        )
        assert big.overlap > 0 and big.t_step < big.t_step_serial
        assert big.t_exposed < big.t_step_serial - big.compute_time
    # forced depth sticks; accum_steps=1 cannot overlap
    forced = comm.plan_pod_sync(
        4, 4e9, topo=fitted2, compute_time=0.5, accum_steps=8, overlap=16
    )
    assert forced.overlap == 16 and forced.n_chunks == 16
    with pytest.warns(RuntimeWarning, match="accum_steps"):
        flat = comm.plan_pod_sync(
            4, 4e9, topo=fitted2, compute_time=0.5, accum_steps=1,
            overlap=16,
        )
    assert flat.overlap == 0


def test_apply_updates_bucketed_matches_tree_path():
    """Per-bucket optimizer application == the full-tree path (same grads,
    same clip) within fp tolerance; exact on dyadic data."""
    from repro.comm import bucketing

    rng = np.random.RandomState(6)
    params = {
        "a": jnp.asarray(rng.randn(300, 7).astype(np.float32)),
        "b": jnp.asarray(rng.randn(1000).astype(np.float32)),
    }
    grads = {
        "a": jnp.asarray(
            (rng.randint(-64, 64, (300, 7)) / 32.0).astype(np.float32)
        ),
        "b": jnp.asarray(
            (rng.randint(-64, 64, (1000,)) / 32.0).astype(np.float32)
        ),
    }
    st = adamw.init_state(params)
    ocfg = adamw.AdamWConfig(lr=1e-2, grad_clip=1e9)  # no clip: exact path
    p_tree, s_tree, m_tree = adamw.apply_updates(params, grads, st, ocfg)
    layout = bucketing.plan_buckets(grads, 1024, reverse=True)
    buckets = bucketing.pack_buckets(layout, grads)
    p_b, s_b, m_b = adamw.apply_updates_bucketed(
        params, buckets, layout, st, ocfg
    )
    for a, b in zip(jax.tree.leaves(p_tree), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(
        float(m_tree["grad_norm"]), float(m_b["grad_norm"]), rtol=1e-6
    )
    # with clipping active the scale comes from the bucket-partial norm
    ocfg2 = adamw.AdamWConfig(lr=1e-2, grad_clip=0.5)
    p_t2, _, _ = adamw.apply_updates(params, grads, st, ocfg2)
    p_b2, _, _ = adamw.apply_updates_bucketed(
        params, buckets, layout, st, ocfg2
    )
    for a, b in zip(jax.tree.leaves(p_t2), jax.tree.leaves(p_b2)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-6
        )


def test_overlap_config_requires_pod_mesh_to_activate():
    """On a single-pod mesh overlap stays off regardless of the knob (no
    DCN seam to hide), and the serial step still runs."""
    cfg, step, params, opt = _setup(accum_steps=2, overlap="auto",
                                    compute_time=1.0)
    tokens = jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    p, o, m = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    decision = train_steps.plan_pod_sync(
        cfg,
        train_steps.TrainConfig(accum_steps=2, overlap="auto",
                                compute_time=1.0),
        n_pods=1,
    )
    assert decision.overlap == 0
