"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU).

Shape/dtype sweeps per kernel, plus hypothesis property tests on the
attention invariants.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import kernel as fk, ops as fops, ref as fref
from repro.kernels.rmsnorm import kernel as rk, ref as rref
from repro.kernels.ssm_scan import kernel as sk, ops as sops, ref as sref

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # tier-1 env has no hypothesis; CI installs it
    from _hypothesis_compat import given, settings, strategies as st

RNG = np.random.RandomState(0)


def _mk_qkv(B, S, H, Hkv, Dh, dtype):
    q = jnp.asarray(RNG.randn(B, S, H, Dh), dtype)
    k = jnp.asarray(RNG.randn(B, S, Hkv, Dh), dtype)
    v = jnp.asarray(RNG.randn(B, S, Hkv, Dh), dtype)
    return q, k, v


ATTN_SWEEP = [
    # B, S, H, Hkv, Dh, causal, softcap, window, dtype, tol
    (2, 64, 4, 2, 16, True, 0.0, 0, jnp.float32, 2e-5),
    (1, 128, 4, 4, 32, True, 30.0, 0, jnp.float32, 2e-5),
    (2, 96, 8, 2, 16, True, 0.0, 32, jnp.float32, 2e-5),
    (1, 64, 2, 1, 16, False, 0.0, 0, jnp.float32, 2e-5),
    (1, 100, 4, 1, 24, True, 0.0, 0, jnp.float32, 2e-5),   # ragged S, Dh
    (2, 64, 4, 2, 16, True, 0.0, 0, jnp.bfloat16, 3e-2),
]


@pytest.mark.parametrize("B,S,H,Hkv,Dh,causal,cap,win,dtype,tol", ATTN_SWEEP)
def test_flash_attention_matches_ref(B, S, H, Hkv, Dh, causal, cap, win, dtype, tol):
    q, k, v = _mk_qkv(B, S, H, Hkv, Dh, dtype)
    out = fk.flash_mha(q, k, v, causal=causal, logit_softcap=cap,
                       sliding_window=win, block_q=32, block_k=32,
                       interpret=True)
    want = fref.mha_reference(q, k, v, causal=causal, logit_softcap=cap,
                              sliding_window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("B,S,H,Hkv,Dh,causal,cap,win,dtype,tol", ATTN_SWEEP)
def test_chunked_mha_matches_ref(B, S, H, Hkv, Dh, causal, cap, win, dtype, tol):
    q, k, v = _mk_qkv(B, S, H, Hkv, Dh, dtype)
    out = fops._chunked_mha(q, k, v, causal, cap, win, chunk=32)
    want = fref.mha_reference(q, k, v, causal=causal, logit_softcap=cap,
                              sliding_window=win)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@given(
    s=st.integers(8, 80),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
)
@settings(max_examples=10, deadline=None)
def test_attention_row_stochastic(s, h, g):
    """Causal attention output rows are convex combinations of V rows:
    with V == const c, output == c."""
    B, Dh = 1, 16
    q = jnp.asarray(RNG.randn(B, s, h * g, Dh), jnp.float32)
    k = jnp.asarray(RNG.randn(B, s, h, Dh), jnp.float32)
    v = jnp.full((B, s, h, Dh), 3.25, jnp.float32)
    out = fops._chunked_mha(q, k, v, True, 0.0, 0, chunk=16)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-5)


def test_flash_grad_matches_ref():
    """Kernel forward with the custom-VJP (chunked) backward vs full ref."""
    B, S, H, Hkv, Dh = 1, 64, 2, 1, 16
    q, k, v = _mk_qkv(B, S, H, Hkv, Dh, jnp.float32)

    def f_k(q, k, v):
        return jnp.sum(fops.mha(q, k, v, use_kernel=True, interpret=True) ** 2)

    def f_r(q, k, v):
        return jnp.sum(fref.mha_reference(q, k, v) ** 2)

    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


RMS_SWEEP = [
    ((4, 32, 64), jnp.float32),
    ((3, 100), jnp.float32),
    ((1, 7, 33), jnp.float32),
    ((4, 32, 64), jnp.bfloat16),
    ((513, 128), jnp.bfloat16),
]


@pytest.mark.parametrize("shape,dtype", RMS_SWEEP)
def test_rmsnorm_matches_ref(shape, dtype):
    x = jnp.asarray(RNG.randn(*shape), dtype)
    w = jnp.asarray(RNG.randn(shape[-1]), jnp.float32)
    out = rk.rmsnorm(x, w, interpret=True, block_rows=64)
    want = rref.rmsnorm_reference(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=2e-2 if dtype == jnp.bfloat16 else 1e-5, rtol=2e-2,
    )


@given(st.integers(2, 200))
@settings(max_examples=10, deadline=None)
def test_rmsnorm_unit_scale_property(d):
    """With w == 1, output rows have mean-square ~= 1."""
    x = jnp.asarray(RNG.randn(3, d) * 7.0, jnp.float32)
    out = rk.rmsnorm(x, jnp.ones((d,)), interpret=True)
    ms = np.mean(np.asarray(out) ** 2, axis=-1)
    np.testing.assert_allclose(ms, 1.0, rtol=1e-3)


SSM_SWEEP = [
    (2, 64, 3, 8, 16, 32),
    (1, 100, 2, 16, 8, 32),    # ragged S
    (2, 128, 4, 8, 16, 64),
    (1, 33, 1, 4, 4, 16),
]


@pytest.mark.parametrize("B,S,H,P,N,chunk", SSM_SWEEP)
def test_ssd_kernel_matches_ref(B, S, H, P, N, chunk):
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.randn(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    D = jnp.asarray(RNG.randn(H), jnp.float32)
    want = sref.selective_scan_reference(x, dt, A, Bm, Cm, D)
    got = sk.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-3)
    got2 = sops._chunked_jnp(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), atol=2e-4, rtol=2e-3)


def test_ssm_decode_matches_scan():
    B, S, H, P, N = 2, 48, 3, 8, 16
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.randn(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    D = jnp.asarray(RNG.randn(H), jnp.float32)
    want = sref.selective_scan_reference(x, dt, A, Bm, Cm, D)
    st_ = jnp.zeros((B, H, N, P))
    outs = []
    for t in range(S):
        y, st_ = sops.decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], D, st_)
        outs.append(y)
    np.testing.assert_allclose(
        np.asarray(jnp.stack(outs, 1)), np.asarray(want), atol=1e-4, rtol=1e-3
    )


def test_ssm_final_state_matches_sequential():
    B, S, H, P, N = 1, 50, 2, 4, 8
    x = jnp.asarray(RNG.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.randn(B, S, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(RNG.randn(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.randn(B, S, N), jnp.float32)
    st_ = jnp.zeros((B, H, N, P))
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A)
        st_ = st_ * decay[..., None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, t], x[:, t] * dt[:, t][..., None]
        )
    got = sops.final_state(x, dt, A, Bm, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(st_), atol=1e-4, rtol=1e-3)
