"""Multi-device collective tests (8 fake CPU devices via subprocess).

These run the executable paper schedules (repro.comm) and the pod-mode
train steps on a (2 mach x 4 core) / (2 pod x 2 data x 2 model) mesh and
check numerics.  Subprocesses are required because the device count must
be fixed before jax initializes.

The collective cases are *registry-driven*: the subprocess iterates every
registered executable (collective, strategy) pair for its collective --
including the broadcast / all_gather impls the registry redesign added --
and checks each against its jnp/numpy reference, so newly registered
strategies are covered automatically instead of hand-enumerated.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

COLLECTIVE_REFS = [
    "all_reduce", "all_to_all", "all_gather", "broadcast", "reduce_scatter",
]


def run_py(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


# Shared harness: plan every registered executable strategy of one
# collective through CommContext on the topology mirroring the device
# mesh, execute the PlannedCollective inside shard_map, compare to the
# reference.  (ctx.plan(...)() round-trip is exercised at the end.)
HARNESS = """
import jax, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro import comm
from repro.core.topology import paper_smp_3tier, paper_smp_cluster

COLLECTIVE = {collective!r}
mesh = jax.make_mesh((2, 4), ("mach", "core"))
topo = {topo_expr}
ctx = comm.CommContext(topo)
rng = np.random.RandomState(0)

def execute(pc, arr):
    f = shard_map(pc, mesh=mesh, in_specs=P(("mach", "core")),
                  out_specs=P(("mach", "core")))
    return np.asarray(jax.jit(f)(arr))

def reference(collective, x, root=0):
    blocks = x.reshape(8, -1, *x.shape[1:])  # per-proc shards
    if collective == "all_reduce":
        return blocks.sum(axis=0, keepdims=True).repeat(8, 0).reshape(x.shape)
    if collective == "broadcast":
        return np.tile(blocks[root], (8,) + (1,) * (x.ndim - 1))
    if collective == "all_gather":
        return np.tile(x, (8,) + (1,) * (x.ndim - 1))
    if collective == "reduce_scatter":
        # every impl returns the mach-major joint-order 1/P shard of the
        # reduced flat vector; the global out-spec concatenation is then
        # exactly that vector
        return blocks.sum(axis=0).reshape(-1)
    raise ValueError(collective)

strategies = [s for c, s in comm.executable_pairs() if c == COLLECTIVE]
assert strategies, f"no executable strategies registered for {{COLLECTIVE}}"

if COLLECTIVE == "all_to_all":
    x = np.arange(8 * 8 * 4, dtype=np.float32).reshape(64, 4)
    want = np.transpose(x.reshape(8, 8, 4), (1, 0, 2)).reshape(64, 4)
    for strat in strategies:
        pc = comm.PlannedCollective(
            plan=comm.plan_for_spec(topo, comm.get_spec(COLLECTIVE, strat),
                                    x.nbytes / 8),
            spec=comm.get_spec(COLLECTIVE, strat),
            mach_axis="mach", core_axis="core")
        got = execute(pc, x)
        assert np.array_equal(got, want), (strat, got)
        print(COLLECTIVE, strat, "ok")
else:
    x = rng.randn(8, 64, 16).astype(np.float32)
    roots = [0, 5] if COLLECTIVE == "broadcast" else [0]
    for strat in strategies:
        spec = comm.get_spec(COLLECTIVE, strat)
        for root in roots:
            pc = comm.PlannedCollective(
                plan=comm.plan_for_spec(topo, spec, x.nbytes / 8, root=root),
                spec=spec, mach_axis="mach", core_axis="core")
            got = execute(pc, x)
            want = reference(COLLECTIVE, x, root=root)
            tol = 2e-2 if spec.lossy else 1e-5
            denom = max(np.abs(want).max(), 1e-9)
            err = np.abs(got - want).max() / denom
            assert err < tol, (strat, root, err)
            print(COLLECTIVE, strat, "root", root, "ok", err)

# the acceptance-criteria round trip: plan -> execute -> matches reference
kw = dict(lossy_ok=True) if COLLECTIVE == "all_reduce" else {{}}
pc = ctx.plan(COLLECTIVE, 1e5, **kw)
arr = (np.arange(8 * 8 * 4, dtype=np.float32).reshape(64, 4)
       if COLLECTIVE == "all_to_all" else rng.randn(8, 64, 16).astype(np.float32))
got = execute(pc, arr)
if COLLECTIVE == "all_to_all":
    want = np.transpose(arr.reshape(8, 8, 4), (1, 0, 2)).reshape(64, 4)
else:
    want = reference(COLLECTIVE, arr, root=pc.plan.root)
tol = 2e-2 if pc.plan.lossy else 1e-5
assert np.abs(got - want).max() / max(np.abs(want).max(), 1e-9) < tol
print("ctx.plan round-trip ok:", pc.describe())
"""


# The same 8 devices planned as the paper's two-tier cluster AND as a
# three-tier (shm / numa / gige) hierarchy: the N-tier topology API must
# plan AND execute every registered strategy on both.
TOPO_EXPRS = {
    "2tier": "paper_smp_cluster(n_machines=2, cores=4, nics=2)",
    "3tier": "paper_smp_3tier(n_machines=2, boards=2, cores=2, nics=2)",
}


@pytest.mark.parametrize("tiers", sorted(TOPO_EXPRS))
@pytest.mark.parametrize("collective", COLLECTIVE_REFS)
def test_registered_executables_match_references(collective, tiers):
    """Every registered executable (collective, strategy) pair runs and
    matches its reference on the 8-device (2 mach x 4 core) mesh, planned
    through both the two-tier and the three-tier topology."""
    print(run_py(HARNESS.format(
        collective=collective, topo_expr=TOPO_EXPRS[tiers]
    )))


def test_legacy_manual_all_reduce_view():
    """The deprecated MANUAL_ALL_REDUCE dict still resolves (derived from
    the registry) and its entries run."""
    print(run_py("""
        import functools
        import jax, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as C

        mesh = jax.make_mesh((2, 4), ("mach", "core"))
        x = np.random.RandomState(0).randn(8, 64, 16).astype(np.float32)
        ref = x.sum(axis=0, keepdims=True).repeat(8, 0)
        assert set(C.MANUAL_ALL_REDUCE) == {
            "flat", "hier", "hier_bw", "hier_q8", "hier_bw_q8"}
        for name, tol in [("flat", 1e-6), ("hier", 1e-5), ("hier_q8", 2e-2)]:
            fn = functools.partial(C.MANUAL_ALL_REDUCE[name],
                                   mach_axis="mach", core_axis="core")
            f = shard_map(fn, mesh=mesh, in_specs=P(("mach", "core")),
                          out_specs=P(("mach", "core")))
            out = np.asarray(jax.jit(f)(x))
            err = np.abs(out - ref).max() / np.abs(ref).max()
            assert err < tol, (name, err)
            print("legacy all_reduce", name, "ok", err)
    """))


def test_q8_codec_roundtrip_accuracy():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.comm import q8_encode, q8_decode, q8_decode_sum
        rng = np.random.RandomState(0)
        for shape in [(100,), (64, 64), (3, 7, 11)]:
            x = jnp.asarray(rng.randn(*shape).astype(np.float32)) * 10
            q, s, n = q8_encode(x)
            y = q8_decode(q, s, n, x.shape, x.dtype)
            err = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
            assert err < 1e-2, (shape, err)
            # the shared gathered-decode path agrees with decode on a
            # stack of one, and averages a stack of two
            y2 = q8_decode_sum(q[None], s[None], n, x.shape, x.dtype)
            np.testing.assert_allclose(np.asarray(y), np.asarray(y2))
            ym = q8_decode_sum(jnp.stack([q, q]), jnp.stack([s, s]), n,
                               x.shape, x.dtype, scale=0.5)
            np.testing.assert_allclose(np.asarray(y), np.asarray(ym),
                                       rtol=1e-6)
        print("q8 codec ok")
    """))


def test_pod_modes_agree_numerically():
    """gspmd (flat baseline) and manual (paper schedule) multi-pod train
    steps produce the same parameters; q8 stays close; 'auto' resolves to
    a runnable wire format via the comm planner."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import lm
        from repro.models.config import reduced_for_smoke
        from repro.optim import adamw
        from repro.sharding import rules
        from repro.train import steps as T

        cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
            compute_dtype="float32", n_layers=2)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pol = rules.ShardingPolicy()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_state(params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}

        from repro import comm
        decision = T.plan_pod_sync(
            cfg, T.TrainConfig(pod_mode="manual", pod_sync="auto"), 2)
        assert decision.fmt in comm.POD_SYNC_FORMATS, decision
        assert T.resolve_pod_sync(
            cfg, T.TrainConfig(pod_mode="manual", pod_sync="auto"), 2
        ) == decision.fmt
        print("auto pod_sync resolves to", decision.describe())

        outs = {}
        for mode, sync in [("gspmd", "flat"), ("manual", "flat"),
                           ("manual", "q8")]:
            tcfg = T.TrainConfig(pod_mode=mode, pod_sync=sync,
                                 use_kernel=False)
            step, bspecs = T.make_train_step(
                cfg, tcfg, adamw.AdamWConfig(lr=1e-2), mesh, pol)
            mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
            with mesh_ctx:
                n = lambda s: jax.tree.map(
                    lambda sp: NamedSharding(mesh, sp), s,
                    is_leaf=lambda x: isinstance(x, P))
                jb = jax.device_put(batch, n(bspecs))
                p2, o2, m = jax.jit(step)(params, opt, jb)
            outs[(mode, sync)] = (jax.tree.map(np.asarray, p2),
                                  float(m["loss"]))

        base_p, base_l = outs[("gspmd", "flat")]
        man_p, man_l = outs[("manual", "flat")]
        assert abs(base_l - man_l) < 1e-4, (base_l, man_l)
        for a, b in zip(jax.tree.leaves(base_p), jax.tree.leaves(man_p)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        q8_p, q8_l = outs[("manual", "q8")]
        assert abs(q8_l - man_l) < 1e-2
        # q8 is lossy but must stay close after one step
        num = sum(float(np.abs(a - b).max())
                  for a, b in zip(jax.tree.leaves(man_p),
                                  jax.tree.leaves(q8_p)))
        assert num < 1.0, num
        print("pod modes ok", base_l, man_l, q8_l)
    """))


def test_bucketed_rs_pod_sync_matches_monolithic():
    """The perf-opt acceptance: bucketed 'rs' pod sync is numerically equal
    to the monolithic flat path, and bucketed 'rs_q8' stays within q8
    tolerance -- in both the shard_map reference and the vmap-mode
    (train-step) combiners, on 8 fake devices."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro import comm

        rng = np.random.RandomState(0)
        tree = {
            "wa": rng.randn(8, 100, 17).astype(np.float32),
            "wb": rng.randn(8, 333).astype(np.float32),
            "wc": rng.randn(8, 65).astype(np.float32),
        }
        want = {k: v.mean(axis=0) for k, v in tree.items()}

        # shard_map reference path over an 8-pod mesh
        mesh = jax.make_mesh((8,), ("pod",))
        def run(fmt, bucket_bytes):
            f = jax.jit(shard_map(
                lambda g: comm.pod_sync_grads(
                    g, fmt, "pod", bucket_bytes=bucket_bytes),
                mesh=mesh, in_specs=P("pod"), out_specs=P(),
                check_rep=False))
            return f({k: jnp.asarray(v) for k, v in tree.items()})

        mono_flat = run("flat", 0)
        for fmt, bb, tol in [("rs", 2048, 1e-6), ("rs", 977, 1e-6),
                             ("rs_q8", 2048, 5e-2)]:
            got = run(fmt, bb)
            for k in tree:
                a = np.asarray(got[k])
                b = np.asarray(mono_flat[k]).reshape(a.shape)
                err = np.abs(a - b).max() / np.abs(b).max()
                assert err < tol, (fmt, bb, k, err)
            print("shard_map bucketed", fmt, bb, "ok")

        # vmap-mode combiners under a ('pod','data','model') mesh
        mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        tree2 = {k: jnp.asarray(v[:2]) for k, v in tree.items()}
        want2 = {k: np.asarray(v)[:2].mean(axis=0) for k, v in tree.items()}
        gspecs = {k: P("pod", *([None] * (tree2[k].ndim - 1)))
                  for k in tree2}
        with mesh2:
            mono = jax.jit(lambda g: comm.pod_combine(
                g, 2, gspecs, fmt="flat"))(tree2)
            for fmt, bb, tol in [("rs", 0, 1e-6), ("rs", 1024, 1e-6),
                                 ("rs_q8", 1024, 5e-2)]:
                got = jax.jit(lambda g, fmt=fmt, bb=bb: comm.pod_combine(
                    g, 2, gspecs, fmt=fmt, bucket_bytes=bb))(tree2)
                for k in tree2:
                    a, b = np.asarray(got[k]), np.asarray(mono[k])
                    err = np.abs(a - b).max() / np.abs(b).max()
                    assert err < tol, (fmt, bb, k, err)
                print("vmap bucketed", fmt, bb, "ok")
        print("bucketed rs pod sync ok")
    """))


def test_overlapped_accumulation_matches_serial():
    """Perf-opt acceptance: the compute-overlapped path (per-microbatch
    partial-mean syncs, reverse-layer buckets, per-bucket optimizer) is a
    pure reordering.  (a) On dyadic data the microbatched combine is
    BIT-IDENTICAL to the serial combine for the exact formats and within
    codec tolerance for q8; (b) a full manual-mode train step with
    overlap forced produces the same parameters as the serial step."""
    print(run_py("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import comm
        from repro.configs import get_config
        from repro.models import lm
        from repro.models.config import reduced_for_smoke
        from repro.optim import adamw
        from repro.sharding import rules
        from repro.train import steps as T

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        # (a) grad-level: overlapped accumulation == serial, bitwise
        rng = np.random.RandomState(0)
        tree = {
            "wa": (rng.randint(-128, 128, (4, 2, 100, 17)) / 64.0
                   ).astype(np.float32),
            "wb": (rng.randint(-128, 128, (4, 2, 333)) / 64.0
                   ).astype(np.float32),
        }
        serial_in = {k: jnp.asarray(v.mean(axis=0)) for k, v in tree.items()}
        with mesh:
            want = jax.jit(
                lambda t: comm.pod_combine(t, 2, fmt="flat")
            )(serial_in)
            for fmt, exact in [("flat", True), ("rs", True),
                               ("q8", False), ("rs_q8", False)]:
                got = jax.jit(
                    lambda t, fmt=fmt: comm.pod_combine_microbatched(
                        t, 2, fmt=fmt, bucket_bytes=1024)
                )({k: jnp.asarray(v) for k, v in tree.items()})
                for k in tree:
                    a, b = np.asarray(got[k]), np.asarray(want[k])
                    if exact:
                        assert np.array_equal(a, b), (fmt, k)
                    else:
                        err = np.abs(a - b).max() / np.abs(b).max()
                        assert err < 5e-2, (fmt, k, err)
                print("microbatched combine", fmt,
                      "bit-identical" if exact else "within q8 tol")

        # (b) step-level: overlapped train step == serial train step
        cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
            compute_dtype="float32", n_layers=2)
        pol = rules.ShardingPolicy()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_state(params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}
        base = T.TrainConfig(pod_mode="manual", pod_sync="rs",
                             accum_steps=4, use_kernel=False)
        outs = {}
        for name, tcfg in [
            ("serial", base),
            ("overlapped", dataclasses.replace(
                base, overlap=2, compute_time=0.1)),
        ]:
            step, bspecs = T.make_train_step(
                cfg, tcfg, adamw.AdamWConfig(lr=1e-2), mesh, pol)
            with mesh:
                n = lambda s: jax.tree.map(
                    lambda sp: NamedSharding(mesh, sp), s,
                    is_leaf=lambda x: isinstance(x, P))
                jb = jax.device_put(batch, n(bspecs))
                p2, o2, m = jax.jit(step)(params, opt, jb)
            outs[name] = (jax.tree.map(np.asarray, p2), float(m["loss"]))
        (ps, ls), (po, lo) = outs["serial"], outs["overlapped"]
        assert abs(ls - lo) < 1e-4, (ls, lo)
        for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(po)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        print("overlapped step == serial step ok", ls, lo)

        # the planner actually selects overlap when the shadow is big
        # (dispatch_cost=0 isolates the mechanics from the committed
        # BENCH_step fixture's fitted per-issue overhead)
        dec = T.plan_pod_sync(
            cfg, dataclasses.replace(base, pod_sync="auto", overlap="auto",
                                     compute_time=5.0), 2, chips_per_pod=1,
            dispatch_cost=0.0)
        assert dec.overlap > 0, dec
        assert dec.t_step <= dec.t_step_serial + 1e-15
        print("auto overlap decision:", dec.describe())
    """))


def test_q8_sharding_constraint_applies_on_mesh():
    """Satellite regression for the silently-swallowed constraint: under a
    real ('pod','data','model') mesh the q8 combiner's sharding constraints
    must APPLY (Sharding custom-calls in the lowered HLO, no fallback
    warning); outside a mesh the fallback warns exactly once."""
    print(run_py("""
        import warnings
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import comm
        from repro.comm import grad_sync

        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.randn(2, 16, 256).astype(np.float32))
        tree = {"w": g}
        gspecs = {"w": P("pod", "data", None)}
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

        with mesh:
            with warnings.catch_warnings():
                warnings.simplefilter("error", RuntimeWarning)  # no fallback
                lowered = jax.jit(
                    lambda t: comm.pod_combine_q8(t, 2, gspecs)
                ).lower(tree)
                out = jax.jit(
                    lambda t: comm.pod_combine_q8(t, 2, gspecs)
                )(tree)
        hlo = lowered.as_text()
        assert "Sharding" in hlo, "no sharding custom-calls in lowered HLO"
        want = np.asarray(g).mean(axis=0)
        err = np.abs(np.asarray(out["w"]) - want).max() / np.abs(want).max()
        assert err < 5e-2, err
        print("constraint applied on mesh, err", err)

        # outside any mesh: narrow fallback path, warns exactly once
        assert not grad_sync._warned_pin_fallback
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out2 = jax.jit(lambda t: comm.pod_combine_q8(t, 2, gspecs))(tree)
            out3 = jax.jit(
                lambda t: comm.pod_combine_q8(t, 2, {"w": P("pod", None, None)})
            )(tree)
        runtime_warnings = [x for x in w
                            if issubclass(x.category, RuntimeWarning)
                            and "sharding constraint" in str(x.message)]
        assert len(runtime_warnings) == 1, len(runtime_warnings)
        assert grad_sync._warned_pin_fallback
        np.testing.assert_allclose(np.asarray(out2["w"]), want, atol=1e-1)
        print("fallback warns once outside mesh ok")
    """))


def test_pipeline_parallel_stage():
    """GPipe-style pipeline over a 'pipe' axis with ppermute: outputs match
    the sequential reference (PP support at small scale)."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.sharding.pipeline import pipeline_apply

        n_stage, n_micro, d = 8, 16, 16
        rng = np.random.RandomState(0)
        ws = jnp.asarray(rng.randn(n_stage, d, d).astype(np.float32) * 0.3)
        xs = jnp.asarray(rng.randn(n_micro, 4, d).astype(np.float32))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        # sequential reference
        ref = xs
        for i in range(n_stage):
            ref = stage_fn(ws[i], ref)

        mesh = jax.make_mesh((8,), ("pipe",))
        got = pipeline_apply(stage_fn, ws, xs, mesh, n_stage=n_stage)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)
        print("pipeline ok")
    """))
