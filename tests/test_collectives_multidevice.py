"""Multi-device collective tests (8 fake CPU devices via subprocess).

These run the executable paper schedules (core.collectives) and the
pod-mode train steps on a (2 mach x 4 core) / (2 pod x 2 data x 2 model)
mesh and check numerics.  Subprocesses are required because the device
count must be fixed before jax initializes.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_py(body: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_manual_collectives_match_references():
    print(run_py("""
        import jax, functools, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as C

        mesh = jax.make_mesh((2, 4), ("mach", "core"))
        x = np.random.RandomState(0).randn(8, 64, 16).astype(np.float32)
        ref = x.sum(axis=0, keepdims=True).repeat(8, 0)

        def run(fn):
            f = jax.shard_map(
                functools.partial(fn, mach_axis="mach", core_axis="core"),
                mesh=mesh, in_specs=P(("mach", "core")),
                out_specs=P(("mach", "core")))
            return np.asarray(jax.jit(f)(x))

        for name, tol in [("flat", 1e-6), ("hier", 1e-5), ("hier_bw", 1e-5),
                          ("hier_q8", 2e-2), ("hier_bw_q8", 2e-2)]:
            out = run(C.MANUAL_ALL_REDUCE[name])
            err = np.abs(out - ref).max() / np.abs(ref).max()
            assert err < tol, (name, err)
            print("all_reduce", name, "ok", err)

        # all-to-all: global block transpose
        x2 = np.arange(8 * 8 * 4, dtype=np.float32).reshape(64, 4)
        want = np.transpose(x2.reshape(8, 8, 4), (1, 0, 2)).reshape(64, 4)
        for fn in (C.manual_all_to_all_flat, C.manual_all_to_all_hier):
            f = jax.shard_map(
                functools.partial(fn, mach_axis="mach", core_axis="core"),
                mesh=mesh, in_specs=P(("mach", "core")),
                out_specs=P(("mach", "core")))
            got = np.asarray(jax.jit(f)(x2))
            assert np.array_equal(got, want), fn.__name__
            print("all_to_all", fn.__name__, "ok")
    """))


def test_q8_codec_roundtrip_accuracy():
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.collectives import q8_encode, q8_decode
        rng = np.random.RandomState(0)
        for shape in [(100,), (64, 64), (3, 7, 11)]:
            x = jnp.asarray(rng.randn(*shape).astype(np.float32)) * 10
            q, s, n = q8_encode(x)
            y = q8_decode(q, s, n, x.shape, x.dtype)
            err = float(jnp.max(jnp.abs(x - y)) / jnp.max(jnp.abs(x)))
            assert err < 1e-2, (shape, err)
        print("q8 codec ok")
    """))


def test_pod_modes_agree_numerically():
    """gspmd (flat baseline) and manual (paper schedule) multi-pod train
    steps produce the same parameters; q8 stays close."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import lm
        from repro.models.config import reduced_for_smoke
        from repro.optim import adamw
        from repro.sharding import rules
        from repro.train import steps as T

        cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
            compute_dtype="float32", n_layers=2)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pol = rules.ShardingPolicy()
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init_state(params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                    cfg.vocab_size)
        batch = {"tokens": tokens, "labels": tokens}

        outs = {}
        for mode, sync in [("gspmd", "flat"), ("manual", "flat"),
                           ("manual", "q8")]:
            tcfg = T.TrainConfig(pod_mode=mode, pod_sync=sync,
                                 use_kernel=False)
            step, bspecs = T.make_train_step(
                cfg, tcfg, adamw.AdamWConfig(lr=1e-2), mesh, pol)
            with jax.set_mesh(mesh):
                n = lambda s: jax.tree.map(
                    lambda sp: NamedSharding(mesh, sp), s,
                    is_leaf=lambda x: isinstance(x, P))
                jb = jax.device_put(batch, n(bspecs))
                p2, o2, m = jax.jit(step)(params, opt, jb)
            outs[(mode, sync)] = (jax.tree.map(np.asarray, p2),
                                  float(m["loss"]))

        base_p, base_l = outs[("gspmd", "flat")]
        man_p, man_l = outs[("manual", "flat")]
        assert abs(base_l - man_l) < 1e-4, (base_l, man_l)
        for a, b in zip(jax.tree.leaves(base_p), jax.tree.leaves(man_p)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        q8_p, q8_l = outs[("manual", "q8")]
        assert abs(q8_l - man_l) < 1e-2
        # q8 is lossy but must stay close after one step
        num = sum(float(np.abs(a - b).max())
                  for a, b in zip(jax.tree.leaves(man_p),
                                  jax.tree.leaves(q8_p)))
        assert num < 1.0, num
        print("pod modes ok", base_l, man_l, q8_l)
    """))


def test_pipeline_parallel_stage():
    """GPipe-style pipeline over a 'pipe' axis with ppermute: outputs match
    the sequential reference (PP support at small scale)."""
    print(run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.sharding.pipeline import pipeline_apply

        n_stage, n_micro, d = 8, 16, 16
        rng = np.random.RandomState(0)
        ws = jnp.asarray(rng.randn(n_stage, d, d).astype(np.float32) * 0.3)
        xs = jnp.asarray(rng.randn(n_micro, 4, d).astype(np.float32))

        def stage_fn(w, x):
            return jnp.tanh(x @ w)

        # sequential reference
        ref = xs
        for i in range(n_stage):
            ref = stage_fn(ws[i], ref)

        mesh = jax.make_mesh((8,), ("pipe",))
        got = pipeline_apply(stage_fn, ws, xs, mesh, n_stage=n_stage)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5)
        print("pipeline ok")
    """))
