"""Model-driven sharding policy selection (sharding/autopolicy.py)."""

import pytest

from repro.configs import get_config
from repro.sharding.autopolicy import choose_policy, estimate


def test_small_models_fold_big_models_dont():
    folds = {}
    for arch in ["llama3_2_1b", "llama3_2_3b", "rwkv6_1_6b", "zamba2_2_7b",
                 "grok_1_314b", "qwen2_vl_72b", "command_r_35b"]:
        pol, _ = choose_policy(get_config(arch), 256, 4096, accum=2)
        folds[arch] = pol.fold_model
    assert folds["llama3_2_1b"] and folds["llama3_2_3b"]
    assert folds["rwkv6_1_6b"] and folds["zamba2_2_7b"]
    assert not folds["grok_1_314b"]
    assert not folds["qwen2_vl_72b"]
    assert not folds["command_r_35b"]  # borderline, memory guard keeps TP


def test_estimates_rank_matches_measured():
    """The model's tp16-vs-dp256 ordering matches the compiled-HLO wire
    measurements recorded in EXPERIMENTS.md SPerf (llama-1b: 6.7x, rwkv6:
    7.5x, llama-3b: 6.3x measured reductions)."""
    for arch, measured_ratio in [("llama3_2_1b", 6.7), ("rwkv6_1_6b", 7.5),
                                 ("llama3_2_3b", 6.3)]:
        est = estimate(get_config(arch), 256, 4096, accum=2)
        predicted_ratio = est["tp16"].total / est["dp256"].total
        assert predicted_ratio > 1.5, (arch, predicted_ratio)
        # direction must agree; magnitude within ~4x (napkin model)
        assert predicted_ratio / measured_ratio < 4
        assert measured_ratio / predicted_ratio < 4


def test_activation_reduce_scaling():
    """tp16 activation-reduce volume scales linearly with layers and seq."""
    cfg = get_config("llama3_2_1b")
    a = estimate(cfg, 256, 4096, 1)["tp16"].act_reduce_bytes
    b = estimate(cfg.with_(n_layers=32), 256, 4096, 1)["tp16"].act_reduce_bytes
    c = estimate(cfg, 256, 8192, 1)["tp16"].act_reduce_bytes
    assert b == pytest.approx(2 * a)
    assert c == pytest.approx(2 * a)
