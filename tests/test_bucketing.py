"""Bucketing subsystem: tree <-> fixed-byte buckets, cost-chosen sizes.

Covers the pack/unpack round trip (mid-leaf splits, dtype/sharding
grouping, batch dims), the fixed-byte invariant, and the bucket-size
selection built on the pipelined cost view (affine fast path == exact
simulator).
"""

import numpy as np
import pytest

from repro import comm
from repro.comm.bucketing import (
    MIN_BUCKET_BYTES,
    choose_n_chunks,
    choose_overlap,
    overlapped_time_affine,
    pipelined_time_affine,
    simulate_choice,
    stage_affine,
)
from repro.core.topology import paper_smp_cluster, tpu_v5e_cluster

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # tier-1 env has no hypothesis; CI installs it
    from _hypothesis_compat import given, settings, strategies as st


def _tree(rng, batch=()):
    import jax.numpy as jnp

    return {
        "a": jnp.asarray(rng.randn(*batch, 300, 7).astype(np.float32)),
        "b": jnp.asarray(rng.randn(*batch, 1000).astype(np.float32)),
        "c": {"d": jnp.asarray(rng.randn(*batch, 33).astype(np.float32))},
    }


@given(bucket_bytes=st.sampled_from([64, 997, 4096, 10**7]))
@settings(max_examples=10, deadline=None)
def test_pack_unpack_round_trip(bucket_bytes):
    rng = np.random.RandomState(0)
    tree = _tree(rng)
    layout = comm.plan_buckets(tree, bucket_bytes)
    buckets = comm.pack_buckets(layout, tree)
    assert len(buckets) == layout.n_buckets
    back = comm.unpack_buckets(layout, buckets)
    import jax

    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_buckets_are_fixed_size_except_group_tail():
    rng = np.random.RandomState(1)
    tree = _tree(rng)
    layout = comm.plan_buckets(tree, 4096)
    buckets = comm.pack_buckets(layout, tree)
    pos = 0
    for g in layout.groups:
        sizes = [b.shape[-1] for b in buckets[pos:pos + g.n_buckets]]
        pos += g.n_buckets
        assert all(s == g.bucket_elems for s in sizes[:-1])
        assert 0 < sizes[-1] <= g.bucket_elems
        # a leaf bigger than the bucket WAS split mid-tensor
        assert g.n_buckets > 1


def test_batch_ndim_round_trip_and_batchless_unpack():
    import jax

    rng = np.random.RandomState(2)
    tree = _tree(rng, batch=(4,))
    layout = comm.plan_buckets(tree, 2048, batch_ndim=1)
    assert layout.batch_shape == (4,)
    buckets = comm.pack_buckets(layout, tree)
    assert all(b.shape[0] == 4 for b in buckets)
    back = comm.unpack_buckets(layout, buckets)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # combine away the batch dim, unpack with batch_shape=()
    done = [b.mean(axis=0) for b in buckets]
    out = comm.unpack_buckets(layout, done, batch_shape=())
    want = jax.tree.map(lambda x: np.asarray(x).mean(axis=0), tree)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(out)):
        np.testing.assert_allclose(a, np.asarray(b), rtol=1e-5, atol=1e-6)


def test_dtype_and_sharding_grouping():
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    rng = np.random.RandomState(3)
    tree = {
        "f32": jnp.asarray(rng.randn(100).astype(np.float32)),
        "bf16": jnp.asarray(rng.randn(100)).astype(jnp.bfloat16),
        "f32b": jnp.asarray(rng.randn(50).astype(np.float32)),
    }
    layout = comm.plan_buckets(tree, 10**6)
    assert len(layout.groups) == 2  # f32 + bf16, never mixed
    specs = {"f32": P("data"), "bf16": P("data"), "f32b": P(None)}
    layout2 = comm.plan_buckets(tree, 10**6, specs=specs)
    assert len(layout2.groups) == 3  # sharding splits the f32 group
    buckets = comm.pack_buckets(layout2, tree)
    back = comm.unpack_buckets(layout2, buckets)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(back[k]))


def test_plan_buckets_rejects_bad_input():
    import jax.numpy as jnp

    with pytest.raises(ValueError, match="positive"):
        comm.plan_buckets({"a": jnp.zeros(3)}, 0)
    with pytest.raises(ValueError, match="empty"):
        comm.plan_buckets({}, 1024)
    with pytest.raises(ValueError, match="batch shape"):
        comm.plan_buckets(
            {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4, 3))},
            1024, batch_ndim=1,
        )


# ----------------------------------------------------------------------
# Cost-model-chosen bucket size
# ----------------------------------------------------------------------

def test_choose_n_chunks_affine_matches_exact_simulator():
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    spec = comm.get_spec("all_reduce", "hier_par_bw")
    build = lambda m: spec.build_schedule(topo, m, payloads=False)
    stages = stage_affine(build)
    for n in (1, 2, 8, 32):
        exact = simulate_choice(build, 1e8, n).t_pipelined
        aff = pipelined_time_affine(stages, 1e8, n)
        assert aff == pytest.approx(exact, rel=1e-9), n


def test_choose_n_chunks_trades_alpha_against_overlap():
    """Large gradients on a two-tier cluster bucket (overlap wins); tiny
    messages stay monolithic (alpha amortization wins); and the choice is
    never modelled slower than monolithic."""
    topo = tpu_v5e_cluster(n_pods=2)
    spec = comm.get_spec("all_reduce", "hier_par_bw")
    build = lambda m: spec.build_schedule(topo, m, payloads=False)
    big = choose_n_chunks(build, 4e9)
    assert big.n_chunks > 1
    assert big.t_pipelined < big.t_monolithic
    assert big.bucket_bytes >= MIN_BUCKET_BYTES
    small = choose_n_chunks(build, 8192.0)
    assert small.n_chunks == 1
    assert small.t_pipelined == small.t_monolithic


def test_context_plan_bucketed():
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    ch = ctx.plan_bucketed("all_reduce", 4e9)
    assert ch.t_pipelined <= ch.t_monolithic
    assert ch.n_chunks >= 1
    pinned = ctx.plan_bucketed("all_reduce", 4e9, strategy="hier_par_bw")
    assert pinned.t_pipelined <= pinned.t_monolithic
    rs = ctx.plan_bucketed("reduce_scatter", 4e9)
    assert rs.t_pipelined <= rs.t_monolithic


def test_reverse_layer_layout_round_trips_and_reorders():
    """Satellite: the reverse-layer bucket layout round-trips exactly
    through pack/unpack, and bucket 0 holds the LAST leaf's data (the
    first gradients backward produces)."""
    import jax

    rng = np.random.RandomState(4)
    tree = _tree(rng)
    fwd = comm.plan_buckets(tree, 1024)
    rev = comm.plan_buckets(tree, 1024, reverse=True)
    leaves = jax.tree.leaves(tree)
    # same leaf set, mirrored concatenation order
    assert [s.leaf_index for g in rev.groups for s in g.slots] == list(
        reversed([s.leaf_index for g in fwd.groups for s in g.slots])
    )
    buckets = comm.pack_buckets(rev, tree)
    assert len(buckets) == rev.n_buckets
    last = np.asarray(leaves[-1]).reshape(-1)
    np.testing.assert_array_equal(
        np.asarray(buckets[0])[: last.size], last
    )
    back = comm.unpack_buckets(rev, buckets)
    for a, b in zip(leaves, jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # batch dims compose with the reverse layout
    tree4 = _tree(rng, batch=(4,))
    rev4 = comm.plan_buckets(tree4, 2048, batch_ndim=1, reverse=True)
    back4 = comm.unpack_buckets(rev4, comm.pack_buckets(rev4, tree4))
    for a, b in zip(jax.tree.leaves(tree4), jax.tree.leaves(back4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_overlapped_affine_matches_exact_simulator():
    from repro.core.simulator import simulate_overlapped

    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    spec = comm.get_spec("all_reduce", "hier_par_bw")
    build = lambda m: spec.build_schedule(topo, m, payloads=False)
    stages = stage_affine(build)
    for n in (1, 2, 8, 32):
        for c in (0.0, 1e-4, 1e-2):
            exact = simulate_overlapped(build, 1e8, n, c).t_overlapped
            aff = overlapped_time_affine(stages, 1e8, n, c)
            assert aff == pytest.approx(exact, rel=1e-9), (n, c)


def test_choose_overlap_hides_comm_under_compute():
    """With a generous compute shadow the overlap sweep picks deep
    chunking and exposes (almost) only the chunk latency; with no shadow
    it degenerates to the pipelined choice."""
    topo = tpu_v5e_cluster(n_pods=2)
    spec = comm.get_spec("all_reduce", "hier_par_bw")
    build = lambda m: spec.build_schedule(topo, m, payloads=False)
    serial = choose_n_chunks(build, 4e9)
    big = choose_overlap(build, 4e9, compute_time=1.0)
    assert big.n_chunks > 1
    assert big.t_overlapped < big.t_serial
    assert big.t_exposed < serial.t_pipelined
    none = choose_overlap(build, 4e9, compute_time=0.0)
    assert none.t_overlapped == pytest.approx(serial.t_pipelined, rel=1e-9)
    pinned = choose_overlap(build, 4e9, compute_time=1.0, n_chunks=4)
    assert pinned.n_chunks == 4


def test_microbatched_combine_matches_serial_bitwise():
    """Satellite: overlapped accumulation (one partial-mean combine per
    microbatch) produces bit-identical grads vs the serial path for the
    exact formats, and codec-tolerance grads for q8 -- on dyadic data whose
    sums are exactly representable, so any mismatch is structural."""
    import jax.numpy as jnp

    rng = np.random.RandomState(5)
    tree = {
        "a": (rng.randint(-128, 128, (4, 2, 300, 7)) / 64.0).astype(
            np.float32
        ),
        "b": (rng.randint(-128, 128, (4, 2, 1000)) / 64.0).astype(
            np.float32
        ),
    }
    serial_in = {k: jnp.asarray(v.mean(axis=0)) for k, v in tree.items()}
    want = comm.pod_combine(serial_in, 2, fmt="flat")
    for fmt, exact in [("flat", True), ("rs", True), ("q8", False)]:
        got = comm.pod_combine_microbatched(
            {k: jnp.asarray(v) for k, v in tree.items()}, 2, fmt=fmt,
            bucket_bytes=2048,
        )
        for k in tree:
            a, b = np.asarray(got[k]), np.asarray(want[k])
            if exact:
                np.testing.assert_array_equal(a, b, err_msg=(fmt, k))
            else:
                assert np.abs(a - b).max() / np.abs(b).max() < 5e-2, (fmt, k)


def test_pod_sync_builder_byte_accounting():
    """The rs composition moves the same global bytes as the bw all-reduce
    (RS half + AG half), and the q8 compositions scale only the global
    tier by the q8 factor."""
    topo = tpu_v5e_cluster(n_pods=2)
    m = 1e6
    flat = comm.pod_sync_builder(topo, "flat")(m)
    rs = comm.pod_sync_builder(topo, "rs")(m)
    rs_q8 = comm.pod_sync_builder(topo, "rs_q8")(m)
    assert rs.total_global_bytes() == pytest.approx(
        flat.total_global_bytes(), rel=1e-6
    )
    assert rs_q8.total_global_bytes() == pytest.approx(
        rs.total_global_bytes() * comm.Q8_GLOBAL_FACTOR, rel=1e-6
    )
