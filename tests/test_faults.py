"""Fault injection, degraded-topology re-planning, and elastic recovery."""

import json

import pytest

from repro.comm.health import (
    ReplanMonitor,
    RetryPolicy,
    StepWatchdog,
    retry_with_backoff,
)
from repro.core.topology import topology_preset
from repro.sim import (
    Engine,
    FaultInjector,
    FaultSpec,
    SimCluster,
    get_scenario,
    random_faults,
    run_scenario,
    scale_faults,
)


def _topo(fanout=(2, 4, 2)):
    return topology_preset("v5e_3tier", 2).with_shape(fanout)


# ----------------------------------------------------------------------
# Degraded / shrunk topology views
# ----------------------------------------------------------------------

def test_degraded_topology_prices_worse_and_stays_valid():
    topo = _topo()
    deg = topo.degraded(tier="dcn", beta_scale=8.0, alpha_add=20e-3)
    # Rule-2 monotonicity survived (construction validates); params moved
    tix = len(topo.tiers) - 1
    assert deg.tiers[tix].beta == pytest.approx(topo.tiers[tix].beta * 8.0)
    assert deg.tiers[tix].alpha == pytest.approx(
        topo.tiers[tix].alpha + 20e-3
    )
    healthy = SimCluster(Engine(), topo)
    degraded = SimCluster(Engine(), deg)
    for nbytes in (1 << 16, 1 << 24):
        assert degraded.collective_time(
            "all_reduce", float(nbytes)
        ) > healthy.collective_time("all_reduce", float(nbytes))


def test_degraded_inner_tier_lifts_outer_tiers_for_rule2():
    topo = _topo()
    # degrade the INNERMOST tier past the outer tiers' params: the outer
    # tiers must be lifted (max-clamped) or Rule-2 validation would reject
    deg = topo.degraded(tier=0, beta_scale=1e6, alpha_add=1.0)
    for inner, outer in zip(deg.tiers, deg.tiers[1:]):
        assert inner.alpha <= outer.alpha
        assert inner.beta <= outer.beta


def test_degraded_validation():
    topo = _topo()
    with pytest.raises(ValueError):
        topo.degraded(tier="dcn", beta_scale=0.5)
    with pytest.raises(ValueError):
        topo.degraded(tier="dcn", alpha_add=-1.0)
    with pytest.raises(ValueError):
        topo.degraded(tier="nope", beta_scale=2.0)


def test_shrunk_topology_by_ids_and_count():
    topo = _topo()                      # fanout (2, 4, 2), 16 procs
    by_ids = topo.shrunk([0])           # node 0 lives in outer group 0
    assert by_ids.n_procs == 8
    assert by_ids.fanout[-1] == 1
    by_count = topo.shrunk(1)
    assert by_count.n_procs == by_ids.n_procs
    with pytest.raises(ValueError):
        topo.shrunk(list(range(topo.n_procs)))   # no survivors


def test_shrunk_topology_flips_the_plan():
    """The acceptance-criterion flip: losing an outer group changes the
    best all_reduce strategy at serving payload sizes."""
    topo = _topo()
    healthy = SimCluster(Engine(), topo)
    shrunk = SimCluster(Engine(), topo.shrunk([0]))
    nbytes = float(1 << 16)
    assert healthy.plan_for("all_reduce", nbytes) != shrunk.plan_for(
        "all_reduce", nbytes
    )


# ----------------------------------------------------------------------
# FaultSpec / FaultInjector
# ----------------------------------------------------------------------

def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("meteor_strike", t_start=1.0)
    with pytest.raises(ValueError):
        FaultSpec("link_degrade", t_start=-1.0, beta_scale=2.0)
    with pytest.raises(ValueError):
        FaultSpec("link_degrade", t_start=0.0)      # no degradation given
    with pytest.raises(ValueError):
        FaultSpec("straggler", t_start=0.0, compute_scale=1.0)
    with pytest.raises(ValueError):
        FaultSpec("transient_drop", t_start=0.0, n_drops=0)


def test_injector_applies_and_reverts_link_fault():
    eng = Engine()
    cluster = SimCluster(eng, _topo())
    t_healthy = cluster.collective_time("all_reduce", 1e6)
    spec = FaultSpec("link_degrade", t_start=1.0, duration=2.0,
                     tier="dcn", beta_scale=8.0, alpha_add=1e-3)
    inj = FaultInjector(eng, cluster, [spec])
    inj.arm()
    with pytest.raises(RuntimeError):
        inj.arm()                                   # double-arm refused

    seen = []
    eng.at(2.0, lambda: seen.append(
        cluster.collective_time("all_reduce", 1e6)))
    eng.at(4.0, lambda: seen.append(
        cluster.collective_time("all_reduce", 1e6)))
    eng.run()
    t_degraded, t_after = seen
    assert t_degraded > t_healthy                   # repriced in-window
    assert t_after == pytest.approx(t_healthy)      # reverted after
    assert [(t, a) for t, a, _ in inj.log] == [
        (1.0, "apply"), (3.0, "revert")
    ]


def test_overlapping_link_faults_compose():
    eng = Engine()
    cluster = SimCluster(eng, _topo())
    specs = [
        FaultSpec("link_degrade", t_start=1.0, duration=4.0,
                  tier="dcn", beta_scale=4.0),
        FaultSpec("link_degrade", t_start=2.0, duration=1.0,
                  tier="dcn", beta_scale=2.0),
    ]
    inj = FaultInjector(eng, cluster, specs)
    inj.arm()
    betas = {}
    base = cluster.topo.tiers[-1].beta
    for t in (1.5, 2.5, 3.5, 6.0):
        eng.at(t, lambda t=t: betas.update(
            {t: cluster.topo.tiers[-1].beta}))
    eng.run()
    assert betas[1.5] == pytest.approx(base * 4.0)
    assert betas[2.5] == pytest.approx(base * 8.0)  # stacked, not clobbered
    assert betas[3.5] == pytest.approx(base * 4.0)
    assert betas[6.0] == pytest.approx(base)


def test_random_faults_deterministic():
    a = random_faults(7, 60.0, n_faults=5, n_nodes=4, n_tiers=3)
    b = random_faults(7, 60.0, n_faults=5, n_nodes=4, n_tiers=3)
    assert a == b
    assert a != random_faults(8, 60.0, n_faults=5, n_nodes=4, n_tiers=3)
    assert all(s.t_start + min(s.duration, 0.0) <= 60.0 for s in a)
    doubled = scale_faults(a, 2.0)
    assert [s.t_start for s in doubled] == [2 * s.t_start for s in a]


def test_same_seed_same_schedule_same_metrics():
    """S3 acceptance: one seed fully determines the fault schedule AND the
    resulting metrics rows -- two runs are byte-identical."""
    sc = get_scenario("kill_recovery")
    m1 = run_scenario(sc, "sim")
    m2 = run_scenario(sc, "sim")
    assert m1["faults"] == m2["faults"]
    assert json.dumps(m1, sort_keys=True) == json.dumps(m2, sort_keys=True)


# ----------------------------------------------------------------------
# Serving under faults: the full recovery loop + conservation laws
# ----------------------------------------------------------------------

def test_kill_recovery_full_loop():
    """Node kill -> watchdog detect -> shrunk-topology re-plan (strategy
    flips) -> restore -> resume, with every request eventually served."""
    m = run_scenario(get_scenario("kill_recovery"), "sim")
    assert m["n_completed"] == m["n_requests"]
    assert m["n_recoveries"] == 1
    rec = m["recoveries"][0]
    assert rec["t_detected_s"] > rec["t_kill_s"]
    assert rec["detect_latency_s"] > 0
    assert rec["n_procs_after"] < 16
    assert rec["plan_before"] != rec["plan_after"]   # the re-plan flipped
    assert m["recovery_time_s"] > 0
    assert rec["t_resumed_s"] > rec["t_detected_s"]


def test_littles_law_holds_across_recovery():
    """L = lambda * W must survive a node kill + restart: the time-integral
    of requests in system equals completions/span x mean latency when
    nothing is shed (restarted requests stay in-system from first arrival
    to final finish)."""
    for name in ("smoke", "kill_recovery"):
        m = run_scenario(get_scenario(name), "sim")
        assert m["n_shed"] == 0
        assert m["n_completed"] == m["n_requests"]
        assert m["mean_in_system"] == pytest.approx(
            m["throughput_rps"] * m["latency_mean_s"], rel=1e-6
        ), name


def test_straggler_slows_steps():
    m = run_scenario(get_scenario("straggler"), "sim")
    healthy = run_scenario(get_scenario("straggler").healthy(), "sim")
    assert m["n_slow_steps"] > 0
    assert m["latency_p99_s"] > healthy["latency_p99_s"]


def test_transient_drops_cost_retries():
    sc = get_scenario("smoke").with_(faults=(
        FaultSpec("transient_drop", t_start=1.0, duration=8.0, n_drops=5),
    ))
    m = run_scenario(sc, "sim")
    healthy = run_scenario(sc.healthy(), "sim")
    assert m["n_retries"] >= 1
    assert m["n_completed"] == m["n_requests"]       # retried, not lost
    assert m["latency_p99_s"] >= healthy["latency_p99_s"]


def test_brownout_sheds_instead_of_queueing_forever():
    m = run_scenario(get_scenario("brownout_burst"), "sim")
    assert m["n_shed"] > 0
    assert m["n_completed"] + m["n_shed"] == m["n_requests"]


# ----------------------------------------------------------------------
# comm.health: watchdog, retry, replan monitor
# ----------------------------------------------------------------------

def test_watchdog_verdicts_and_ewma():
    wd = StepWatchdog(expected_s=1.0, alpha=0.5, drift_band=1.5,
                      timeout_factor=5.0)
    assert wd.observe(1.0) == "ok"
    assert wd.observe(2.0) == "slow"              # > 1.5 x reference
    assert wd.observe(100.0) == "lost"            # > timeout_s
    ewma_before = wd.ewma_s
    assert wd.ewma_s == ewma_before               # lost samples excluded
    assert wd.n_slow == 1
    wd.rebase(0.5)
    assert wd.reference_s == 0.5
    assert wd.observe(0.5) == "ok"


def test_watchdog_timeout_tracks_ewma():
    wd = StepWatchdog(expected_s=1.0, alpha=1.0, timeout_factor=3.0)
    assert wd.timeout_s == pytest.approx(3.0)
    wd.observe(2.0)                               # ewma jumps to 2.0
    assert wd.timeout_s == pytest.approx(6.0)


def test_retry_policy_backoff():
    pol = RetryPolicy(max_attempts=4, base_delay_s=0.1, backoff=2.0,
                      max_delay_s=0.3)
    assert [pol.delay(i) for i in range(4)] == pytest.approx(
        [0.1, 0.2, 0.3, 0.3]                      # capped at max_delay_s
    )
    assert pol.total_delay(3) == pytest.approx(0.6)


def test_retry_with_backoff_retries_then_raises():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    slept = []
    assert retry_with_backoff(
        flaky, RetryPolicy(max_attempts=4, base_delay_s=0.01),
        sleep=slept.append,
    ) == "ok"
    assert calls["n"] == 3 and len(slept) == 2

    def always_fails():
        raise RuntimeError("permanent")

    with pytest.raises(RuntimeError, match="permanent"):
        retry_with_backoff(
            always_fails, RetryPolicy(max_attempts=2, base_delay_s=0.0),
            sleep=lambda _: None,
        )

    def wrong_kind():
        raise KeyError("not retriable")

    with pytest.raises(KeyError):                 # no retry on other types
        retry_with_backoff(
            wrong_kind, RetryPolicy(max_attempts=5, base_delay_s=0.0),
            sleep=lambda _: None,
        )


def test_replan_monitor_triggers_after_patience():
    replans = []
    wd = StepWatchdog(expected_s=1.0, alpha=0.01, drift_band=1.5)

    def replan():
        replans.append(True)
        return 2.0                                # new expected step time

    mon = ReplanMonitor(wd, replan, patience=2)
    assert mon.observe(1.0) == "ok"
    assert mon.observe(2.0) == "slow"
    assert mon.observe(2.0) == "replanned"        # patience hit
    assert len(replans) == 1
    assert wd.reference_s == 2.0                  # rebased onto the replan
    assert mon.observe(2.0) == "ok"               # healthy at the new pace


# ----------------------------------------------------------------------
# Elastic recovery in the training loop
# ----------------------------------------------------------------------

def test_loop_node_loss_recovers_via_hook(tmp_path):
    import repro.train.loop as tl

    calls = {"old": 0, "new": 0, "recover": 0}

    def old_step(p, o, b):
        calls["old"] += 1
        return p, o, {"loss": 1.0, "grad_norm": 0.0}

    def new_step(p, o, b):
        calls["new"] += 1
        return p, o, {"loss": 0.5, "grad_norm": 0.0}

    def recover(params, opt_state):
        calls["recover"] += 1
        return new_step, params, opt_state

    class Data:
        def batch(self, step):
            return {}

    import numpy as np

    st = tl.run(old_step, {"w": np.zeros(2)}, {"m": np.zeros(2)}, Data(),
                tl.LoopConfig(total_steps=8, ckpt_every=3, log_every=100,
                              ckpt_dir=str(tmp_path), lose_node_at_step=5),
                recover=recover)
    assert st.step == 8 and len(st.losses) == 8
    assert calls["recover"] == 1
    rec = st.recoveries[0]
    assert rec["lost_at_step"] == 5
    assert rec["restored_from_step"] == 3         # rewound to the ckpt
    assert rec["resumed_at_step"] == 3
    # steps 3..7 re-ran on the new (post-recovery) step function
    assert calls["new"] == 5
    assert st.losses[-1] == 0.5


def test_loop_node_loss_without_hook_propagates(tmp_path):
    import repro.train.loop as tl

    class Data:
        def batch(self, step):
            return {}

    def step(p, o, b):
        return p, o, {"loss": 1.0, "grad_norm": 0.0}

    with pytest.raises(tl.NodeLossError):
        tl.run(step, {}, {}, Data(),
               tl.LoopConfig(total_steps=5, ckpt_every=100, log_every=100,
                             ckpt_dir=str(tmp_path), lose_node_at_step=2))
