"""Core communication model: schedules, simulator, planner, paper claims."""

import math

import pytest

from repro.core import schedules as S
from repro.core.planner import best_plan, enumerate_plans
from repro.core.simulator import (
    ScheduleError,
    check_semantics,
    overlapped_cost_features,
    pipeline_stages,
    pipelined_cost_features,
    simulate_async,
    simulate_overlapped,
    simulate_pipelined,
    simulate_rounds,
    validate,
)
from repro.core.topology import (
    ClusterTopology,
    LinkTier,
    paper_smp_cluster,
    tpu_v5e_cluster,
)

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # tier-1 env has no hypothesis; CI installs it
    from _hypothesis_compat import given, settings, strategies as st

TOPOS = [
    paper_smp_cluster(n_machines=4, cores=4, nics=2),
    paper_smp_cluster(n_machines=8, cores=4, nics=1),
    paper_smp_cluster(n_machines=2, cores=8, nics=4),
    paper_smp_cluster(n_machines=1, cores=4, nics=1),
]
ALL_CELLS = [
    (topo, coll, strat)
    for topo in TOPOS
    for coll, strats in S.GENERATORS.items()
    for strat in strats
]


@pytest.mark.parametrize("topo,coll,strat", ALL_CELLS)
def test_schedule_valid_and_complete(topo, coll, strat):
    """Every generator produces a rule-respecting, semantically complete
    schedule on every topology."""
    sched = S.build(topo, coll, strat, 4096.0, payloads=True)
    validate(sched)
    check_semantics(sched)


@pytest.mark.parametrize("topo,coll,strat", ALL_CELLS)
def test_payload_free_mode_identical(topo, coll, strat):
    """payloads=False (planner fast path) must have identical structure."""
    a = S.build(topo, coll, strat, 4096.0, payloads=True)
    b = S.build(topo, coll, strat, 4096.0, payloads=False)
    assert a.n_rounds == b.n_rounds
    assert a.total_global_bytes() == pytest.approx(b.total_global_bytes())
    assert a.total_local_bytes() == pytest.approx(b.total_local_bytes())
    assert simulate_rounds(a, check=False) == pytest.approx(
        simulate_rounds(b, check=False)
    )


@pytest.mark.parametrize("strat", ["hier_seq", "hier_par"])
def test_hier_schedules_respect_strict_egress(strat):
    """Schedules *designed* for the model keep within machine degree."""
    topo = paper_smp_cluster(n_machines=6, cores=4, nics=2)
    sched = S.build(topo, "broadcast", strat, 1024.0)
    validate(sched, strict_egress=True)


# ----------------------------------------------------------------------
# The paper's analytical claims
# ----------------------------------------------------------------------

def test_c1_intra_machine_broadcast_is_one_write():
    """C1: broadcasting within a machine is O(1) (one shared-memory write),
    not O(log n) messages."""
    topo = paper_smp_cluster(n_machines=1, cores=16, nics=1)
    sched = S.build(topo, "broadcast", "hier_par", 1024.0)
    writes = [op for op in sched.all_ops() if isinstance(op, S.LocalWrite)]
    sends = [op for op in sched.all_ops() if isinstance(op, S.Send)]
    assert len(writes) == 1 and not sends
    flat = S.build(topo, "broadcast", "flat", 1024.0)
    assert flat.n_rounds == math.ceil(math.log2(16))  # what flat models pay


def test_c2_gather_not_inverse_broadcast():
    """C2: optimal gather trees are NOT inverse optimal broadcast trees.

    A degree-n machine broadcasts to n neighbours in one global round after
    one local write; gather needs strictly more rounds (reads cost)."""
    topo = paper_smp_cluster(n_machines=5, cores=4, nics=4)
    bc = S.build(topo, "broadcast", "hier_par", 1024.0)
    ga = S.build(topo, "gather", "hier_par", 1024.0)
    assert ga.n_rounds > bc.n_rounds
    # and gather moves strictly more local (read) bytes than broadcast
    assert ga.total_local_bytes() > bc.total_local_bytes()


def test_c3_parallel_egress_beats_single_leader():
    """Rule 3: degree-aware broadcast needs ceil(log_{d+1} M) global rounds
    vs ceil(log_2 M) for the single-leader hierarchical scheme."""
    topo = paper_smp_cluster(n_machines=27, cores=8, nics=8)
    par = S.build(topo, "broadcast", "hier_par", 1024.0)
    seq = S.build(topo, "broadcast", "hier_seq", 1024.0)
    d = min(topo.degree, topo.procs_per_machine)
    global_rounds_par = sum(
        1 for r in par.rounds
        if any(isinstance(o, S.Send) and not topo.co_located(o.src, o.dst)
               for o in r.ops)
    )
    assert global_rounds_par == math.ceil(math.log(27, d + 1))
    assert simulate_rounds(par) < simulate_rounds(seq)


def test_c4_hier_alltoall_beats_flat():
    """C4 (Kumar et al.): hierarchy-aware all-to-all wins; the gain is
    >= 50% in the latency-dominated regime."""
    topo = paper_smp_cluster(n_machines=8, cores=4, nics=2)
    m = 512.0  # small messages: alpha-dominated, the regime of [3]
    flat = simulate_rounds(S.build(topo, "all_to_all", "flat", m))
    hier = simulate_rounds(S.build(topo, "all_to_all", "hier_par", m))
    assert hier < flat
    assert 1 - hier / flat >= 0.5


def test_flat_alltoall_pays_nic_serialization():
    """The shared-NIC rule: flat all-to-all on 4-core/1-NIC machines takes
    ~4x the per-round time of the same schedule on 4-NIC machines."""
    m = 4096.0
    topo1 = paper_smp_cluster(n_machines=4, cores=4, nics=1)
    topo4 = paper_smp_cluster(n_machines=4, cores=4, nics=4)
    t1 = simulate_rounds(S.build(topo1, "all_to_all", "flat", m))
    t4 = simulate_rounds(S.build(topo4, "all_to_all", "flat", m))
    assert t1 > 2.5 * t4


# ----------------------------------------------------------------------
# Simulator properties
# ----------------------------------------------------------------------

@given(
    m=st.floats(min_value=64, max_value=1e7),
    machines=st.integers(2, 6),
    cores=st.sampled_from([2, 4, 8]),
    nics=st.integers(1, 4),
)
@settings(max_examples=25, deadline=None)
def test_async_never_slower_than_rounds_for_hier(m, machines, cores, nics):
    """Dependency-driven execution can only relax round barriers."""
    topo = paper_smp_cluster(n_machines=machines, cores=cores, nics=nics)
    sched = S.build(topo, "all_reduce", "hier_par", m)
    # allow tiny numerical slack
    assert simulate_async(sched) <= simulate_rounds(sched) * 1.001


@given(
    m=st.floats(min_value=64, max_value=1e6),
    coll=st.sampled_from(list(S.GENERATORS)),
)
@settings(max_examples=30, deadline=None)
def test_cost_monotone_in_message_size(m, coll):
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    for strat in S.GENERATORS[coll]:
        t1 = simulate_rounds(S.build(topo, coll, strat, m, payloads=False), check=False)
        t2 = simulate_rounds(
            S.build(topo, coll, strat, 2 * m, payloads=False), check=False
        )
        assert t2 >= t1


def test_global_bytes_lower_bound_allreduce():
    """No all-reduce schedule beats the 2m(M-1)/M machine-boundary bound."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    m = 1e6
    bound = topo.n_machines * 2 * m * (topo.n_machines - 1) / topo.n_machines
    for strat in S.GENERATORS["all_reduce"]:
        sched = S.build(topo, "all_reduce", strat, m, payloads=False)
        assert sched.total_global_bytes() >= bound * 0.99, strat


# ----------------------------------------------------------------------
# Pipelined (bucketed) view
# ----------------------------------------------------------------------

PIPE_CELLS = [
    ("all_reduce", "hier_par"),
    ("all_reduce", "hier_par_bw"),
    ("reduce_scatter", "hier_par"),
    ("all_gather", "hier_par"),
    ("all_to_all", "hier_par"),
]


@pytest.mark.parametrize("coll,strat", PIPE_CELLS)
@pytest.mark.parametrize("n_chunks", [2, 4, 16])
def test_pipelined_strictly_beats_serial_chunking(coll, strat, n_chunks):
    """The perf-opt acceptance: whenever n_chunks > 1 and the schedule has
    nonzero local work (alongside its global work), the pipelined time is
    strictly below the unpipelined chunked schedule -- overlapping round
    k's local combine with round k+1's global send must pay off."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    build = lambda m: S.build(topo, coll, strat, m, payloads=False)
    pc = simulate_pipelined(build, 1e6, n_chunks)
    kinds = {k for k, t in pc.stages if t > 0}
    assert kinds == {"local", "global"}, pc.stages  # both tiers present
    assert pc.t_pipelined < pc.t_serial
    # and the one-chunk case degenerates to the plain round model
    mono = simulate_pipelined(build, 1e6, 1)
    assert mono.t_pipelined == mono.t_serial
    assert mono.t_chunk == pytest.approx(
        simulate_rounds(build(1e6), check=False), rel=1e-12
    )


def test_pipelined_no_local_work_no_gain():
    """With one proc per machine there is no local tier to overlap: the
    pipelined time equals the serial chunked time (and chunking itself
    only pays extra alphas)."""
    topo = paper_smp_cluster(n_machines=8, cores=1, nics=1)
    build = lambda m: S.build(topo, "all_reduce", "hier_par_bw", m,
                              payloads=False)
    pc = simulate_pipelined(build, 1e6, 8)
    assert {k for k, _ in pc.stages} == {"global"}
    assert pc.t_pipelined == pytest.approx(pc.t_serial)


def test_pipeline_stages_partition_the_rounds():
    """Stages are maximal same-tier runs; their durations sum to the
    round-model total."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    sched = S.build(topo, "all_reduce", "hier_par_bw", 65536.0,
                    payloads=False)
    stages = pipeline_stages(sched)
    kinds = [k for k, _ in stages]
    assert all(a != b for a, b in zip(kinds, kinds[1:]))  # maximal runs
    assert sum(t for _, t in stages) == pytest.approx(
        simulate_rounds(sched, check=False), rel=1e-12
    )


def test_pipelined_cost_features_exact():
    """dot(pipelined_cost_features, params) == simulate_pipelined at the
    linearization point -- calibration's fit applies to pipelined
    schedules unchanged."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    for coll, strat in PIPE_CELLS:
        build = lambda m: S.build(topo, coll, strat, m, payloads=False)
        for n in (1, 3, 8):
            f = pipelined_cost_features(build, 2e5, n)
            t_lin = sum(a * b for a, b in zip(f, topo.param_vector()))
            want = simulate_pipelined(build, 2e5, n, check=False).t_pipelined
            assert t_lin == pytest.approx(want, rel=1e-12), (coll, strat, n)


# ----------------------------------------------------------------------
# Compute-overlapped view
# ----------------------------------------------------------------------

@pytest.mark.parametrize("coll,strat", PIPE_CELLS)
@pytest.mark.parametrize("n_chunks", [2, 4, 16])
def test_overlapped_strictly_beats_serial(coll, strat, n_chunks):
    """The perf-opt acceptance: whenever compute_time > 0 and n_chunks > 1,
    riding the backward shadow beats backward-then-sync."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    build = lambda m: S.build(topo, coll, strat, m, payloads=False)
    for c in (1e-5, 1e-3, 1e-1):
        oc = simulate_overlapped(build, 1e6, n_chunks, c)
        assert oc.t_overlapped < oc.t_serial, (c, oc)
        assert oc.t_exposed >= 0
    # degenerate cases: no compute shadow == the pipelined bound; a single
    # chunk == serial (the whole sync waits for the whole backward)
    oc0 = simulate_overlapped(build, 1e6, n_chunks, 0.0)
    pc = simulate_pipelined(build, 1e6, n_chunks)
    assert oc0.t_overlapped == pytest.approx(pc.t_pipelined, rel=1e-12)
    mono = simulate_overlapped(build, 1e6, 1, 1e-3)
    assert mono.t_overlapped == pytest.approx(mono.t_serial, rel=1e-12)


def test_overlapped_cost_features_exact():
    """dot(features, params) + offset == simulate_overlapped at the
    linearization point: calibration's fit applies to overlapped schedules
    unchanged (compute_time is a measured constant, not a parameter)."""
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    for coll, strat in PIPE_CELLS:
        build = lambda m: S.build(topo, coll, strat, m, payloads=False)
        for n in (1, 3, 8):
            for c in (0.0, 1e-4, 1e-1):
                f, c0 = overlapped_cost_features(build, 2e5, n, c)
                t_lin = sum(a * b for a, b in zip(f, topo.param_vector())) + c0
                want = simulate_overlapped(
                    build, 2e5, n, c, check=False
                ).t_overlapped
                assert t_lin == pytest.approx(want, rel=1e-12), (
                    coll, strat, n, c)


def _chunked(build, m: float, n: int) -> S.Schedule:
    """n back-to-back copies of build(m / n) as one composite schedule."""
    parts = [build(m / n) for _ in range(n)]
    out = S.Schedule(
        f"{parts[0].name}_x{n}", parts[0].collective, parts[0].topo,
        parts[0].nbytes,
    )
    for p in parts:
        out.rounds.extend(p.rounds)
    return out


@pytest.mark.parametrize("coll,strat", [
    ("all_reduce", "hier_par_bw"), ("reduce_scatter", "hier_par"),
])
@pytest.mark.parametrize("n_chunks", [2, 4, 8])
def test_async_view_brackets_pipelined_bound_on_chunked_schedule(
    coll, strat, n_chunks
):
    """ROADMAP "pipelined view for the async simulator": feed the async
    view a chunked schedule and compare to ``simulate_pipelined``.

    Finding (documented in ROADMAP): the async view does NOT reproduce the
    pipeline bound -- it lands between the pipelined and the serial chunked
    time.  The pipelined view treats the tiers as independent resources, but
    under the async view's single-port discipline (Rule 0) the SAME procs
    drive both tiers, so chunk k+1's local stage cannot start while its
    proc's global send of chunk k is in flight.  What async does sharpen is
    the serial bound (round barriers within a chunk relax).  The gap to the
    pipelined bound stays modest (< 30% on these topologies) because the
    bottleneck stage dominates either way.
    """
    for topo in [
        paper_smp_cluster(n_machines=4, cores=4, nics=2),
        paper_smp_cluster(n_machines=2, cores=8, nics=4),
    ]:
        build = lambda m: S.build(topo, coll, strat, m, payloads=True)
        pc = simulate_pipelined(build, 1e6, n_chunks, check=False)
        t_async = simulate_async(_chunked(build, 1e6, n_chunks), check=False)
        assert pc.t_pipelined <= t_async * 1.001, (topo.fanout, pc, t_async)
        assert t_async <= pc.t_serial * 1.001, (topo.fanout, pc, t_async)
        assert t_async <= pc.t_pipelined * 1.30, (topo.fanout, pc, t_async)


# ----------------------------------------------------------------------
# Per-tier Rule 3 + mid-tier volume bounds
# ----------------------------------------------------------------------

def _three_tier(nics: int = 2, degrees=None) -> ClusterTopology:
    return ClusterTopology(
        tiers=(
            LinkTier("shm", alpha=1e-6, beta=1.0 / 2.0e9),
            LinkTier("numa", alpha=3e-6, beta=1.0 / 1.2e9),
            LinkTier("gige", alpha=50e-6, beta=1.0 / 125.0e6),
        ),
        fanout=(2, 2, 4),
        degree=nics,
        write_cost=1e-6,
        assemble_cost=2e-6,
        degrees=degrees,
    )


def test_per_tier_degree_default_matches_legacy():
    """The default degrees vector (unlimited inner, ``degree`` outermost)
    must cost and validate exactly like the pre-degrees model."""
    topo = _three_tier()
    assert topo.degrees == (0, 0, 2)
    assert topo.tier_degree(0) == 0 and topo.tier_degree(2) == 2
    explicit = _three_tier(degrees=(0, 0, 2))
    for coll, strat in PIPE_CELLS:
        a = S.build(topo, coll, strat, 65536.0, payloads=False)
        b = S.build(explicit, coll, strat, 65536.0, payloads=False)
        assert simulate_rounds(a, check=False) == pytest.approx(
            simulate_rounds(b, check=False)
        )


def test_per_tier_degree_serializes_inner_tier():
    """A finite mid-tier degree charges the ceil(usage/degree) Rule-3
    serialization at that boundary -- flat inner fan-outs now pay it."""
    free = _three_tier()
    tight = _three_tier(degrees=(0, 1, 2))
    build = lambda t: S.build(t, "all_reduce", "hier_par_bw", 1e5,
                              payloads=False)
    assert simulate_rounds(build(tight), check=False) > simulate_rounds(
        build(free), check=False
    )
    # async view serializes through the same per-tier link pools
    assert simulate_async(build(tight), check=False) >= simulate_async(
        build(free), check=False
    )
    # strict egress validation rejects the oversubscribing round
    with pytest.raises(ScheduleError, match="tier-1"):
        validate(build(tight), strict_egress=True)


def test_mid_tier_volume_bounds_catch_missing_traffic():
    """check_semantics now bounds EVERY tier boundary's byte volume for the
    reduction collectives: excising a mid-tier ring stage must be caught
    even though the innermost payloads and outermost volume stay intact."""
    topo = _three_tier()
    sched = S.build(topo, "all_reduce", "hier_par_bw", 4096.0, payloads=True)
    check_semantics(sched)  # intact schedule passes
    broken = S.Schedule(sched.name, sched.collective, topo, sched.nbytes)
    for rnd in sched.rounds:
        keep = [
            op for op in rnd.ops
            if not (isinstance(op, S.Send)
                    and topo.tier_index(op.src, op.dst) == 1)
        ]
        if keep:
            broken.rounds.append(S.Round(list(keep)))
    with pytest.raises(ScheduleError, match="tier-1"):
        check_semantics(broken)


# ----------------------------------------------------------------------
# Planner
# ----------------------------------------------------------------------

def test_planner_affine_cost_is_exact():
    topo = paper_smp_cluster(n_machines=4, cores=4, nics=2)
    for coll in S.GENERATORS:
        for m in [256.0, 77777.0, 3e6]:
            plans = enumerate_plans(topo, coll, m)
            for p in plans:
                sched = S.build(topo, coll, p.strategy.replace("_q8", ""), m,
                                payloads=False)
                want = simulate_rounds(sched, check=False)
                if not p.lossy:
                    assert p.t_rounds == pytest.approx(want, rel=1e-9), (
                        coll, p.strategy, m)


def test_planner_picks_hier_for_alltoall_and_small_allreduce():
    topo = tpu_v5e_cluster(n_pods=2)
    assert best_plan(topo, "all_to_all", 1e6).strategy == "hier_par"
    assert best_plan(topo, "all_reduce", 1e4).strategy.startswith("hier")
    # large all-reduce: bandwidth-optimal variant wins
    assert best_plan(topo, "all_reduce", 4e9).strategy == "hier_par_bw"


def test_planner_q8_wins_when_allowed_at_scale():
    topo = tpu_v5e_cluster(n_pods=8)
    p = best_plan(topo, "all_reduce", 4e9, lossy_ok=True)
    assert p.lossy and p.impl == "hier_bw_q8"
    p2 = best_plan(topo, "all_reduce", 4e9, lossy_ok=False)
    assert not p2.lossy
    assert p.t_rounds <= p2.t_rounds


def test_planner_crossover_message_size():
    """The paper's model produces a latency/bandwidth crossover: the tree
    variant wins small messages, the ring variant wins large ones."""
    topo = tpu_v5e_cluster(n_pods=2)
    small = best_plan(topo, "all_reduce", 1e3)
    large = best_plan(topo, "all_reduce", 1e9)
    assert small.strategy == "hier_par"
    assert large.strategy == "hier_par_bw"


def test_topology_validation():
    with pytest.raises(ValueError):
        ClusterTopology(
            n_machines=2, procs_per_machine=2, degree=1,
            local=LinkTier("slow", 1e-3, 1e-6),
            global_=LinkTier("fast", 1e-6, 1e-9),
            write_cost=1e-6, assemble_cost=1e-6,
        )
