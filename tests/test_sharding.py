"""Sharding rules: coverage, rank-correctness, production-mesh divisibility."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.sharding import rules

KEY = jax.random.PRNGKey(0)
MESH_SHAPE = {"data": 16, "model": 16}   # production intra-pod mesh


def _abstract(cfg):
    return jax.eval_shape(lambda: lm.init_params(KEY, cfg))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_specs_cover_all_leaves_with_correct_rank(arch):
    cfg = get_config(arch)
    params = _abstract(cfg)
    pol = rules.ShardingPolicy(shard_vocab=cfg.vocab_size % 16 == 0)
    specs = rules.param_specs(cfg, params, pol)
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_production_mesh_divisibility(arch):
    """Every sharded dim divides by its mesh-axis size (so the dry-run never
    relies on implicit padding for parameters)."""
    cfg = get_config(arch)
    params = _abstract(cfg)
    pol = rules.ShardingPolicy(shard_vocab=cfg.vocab_size % 16 == 0)
    specs = rules.param_specs(cfg, params, pol)

    def check(path, leaf, spec):
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = int(np.prod([MESH_SHAPE[a] for a in axes]))
            assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec, dim)

    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_p, flat_s):
        check(jax.tree_util.keystr(path), leaf, spec)


@pytest.mark.parametrize("arch", ["llama3_2_1b", "grok_1_314b", "rwkv6_1_6b"])
def test_big_matrices_are_sharded(arch):
    """No parameter above 16M elements may be fully replicated."""
    cfg = get_config(arch)
    params = _abstract(cfg)
    pol = rules.ShardingPolicy(shard_vocab=cfg.vocab_size % 16 == 0)
    specs = rules.param_specs(cfg, params, pol)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat_p, flat_s):
        if leaf.size > 16e6:
            assert any(e is not None for e in spec), (
                jax.tree_util.keystr(path), leaf.shape)


def test_batch_specs_modes():
    cfg = get_config("llama3_2_1b")
    pol = rules.ShardingPolicy()
    b1 = rules.batch_specs(cfg, pol)
    assert b1["tokens"] == P("data", None)
    b2 = rules.batch_specs(cfg, pol, pod_axis="pod")
    assert b2["tokens"] == P(("pod", "data"), None)
    vlm = rules.batch_specs(get_config("qwen2_vl_72b"), pol)
    assert vlm["positions"] == P(None, "data", None)
