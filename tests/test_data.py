"""Data pipeline: determinism, sharding disjointness, memmap source."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, MemmapTokens, SyntheticLM, make_pipeline


def test_synthetic_deterministic():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4, seed=42)
    a = SyntheticLM(cfg).batch(7)
    b = SyntheticLM(cfg).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticLM(cfg).batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=50, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    # tokens[t+1] == labels[t] by construction of the (seq_len+1) stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_shards_disjoint_and_deterministic():
    base = dict(vocab_size=1000, seq_len=16, global_batch=8, seed=1)
    s0 = SyntheticLM(DataConfig(**base, n_shards=2, shard_id=0)).batch(3)
    s1 = SyntheticLM(DataConfig(**base, n_shards=2, shard_id=1)).batch(3)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_zipf_marginal_is_skewed():
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=16)
    b = SyntheticLM(cfg).batch(0)
    # token 0 (rank 1) must be much more frequent than the tail
    freq0 = np.mean(b["tokens"] == 0)
    tail = np.mean(b["tokens"] > 500)
    assert freq0 > tail


def test_memmap_source(tmp_path):
    path = tmp_path / "toks.bin"
    arr = np.arange(10000, dtype=np.uint16) % 321
    arr.tofile(path)
    cfg = DataConfig(vocab_size=321, seq_len=32, global_batch=4,
                     kind="memmap", path=str(path))
    pipe = make_pipeline(cfg)
    b1 = pipe.batch(5)
    b2 = MemmapTokens(cfg).batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].max() < 321


def test_bad_shard_config_raises():
    with pytest.raises(ValueError):
        SyntheticLM(DataConfig(vocab_size=10, seq_len=4, global_batch=5,
                               n_shards=2))
