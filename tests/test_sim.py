"""Discrete-event simulator (repro.sim) + dispatch-cost model tests.

Covers: event-loop ordering/tie-break/monotonicity invariants, link-pool
contention, seeded-workload determinism, the exact single-collective
cross-check against ``core.simulator.simulate``, a Little's-law sanity
check on an M/M/1-style single-slot scenario, KV-residency admission,
calibration-JSON plumbing, the per-issue dispatch-cost term, and the
serving engine's stop-token / per-step-latency reporting.
"""

import math

import pytest

from repro.comm import bucketing, registry
from repro.comm.calibrate import CalibrationResult, save_calibration
from repro.comm.context import best_plan
from repro.comm.grad_sync import plan_pod_sync, resolve_dispatch_cost
from repro.core import schedules as S
from repro.core import simulator as core_sim
from repro.core.topology import tpu_v5e_3tier, tpu_v5e_cluster
from repro.sim import (
    Engine,
    LinkPool,
    Request,
    ServingConfig,
    ServingSim,
    SimCluster,
    SimTimeError,
    Trace,
    WorkloadConfig,
    generate_trace,
    get_scenario,
    run_scenario,
)


# ----------------------------------------------------------------------
# Event engine invariants
# ----------------------------------------------------------------------

def test_engine_fires_in_time_priority_insertion_order():
    eng = Engine()
    fired = []
    eng.at(2.0, fired.append, "t2")
    eng.at(1.0, fired.append, "t1-late-priority", priority=5)
    eng.at(1.0, fired.append, "t1-first-inserted")
    eng.at(1.0, fired.append, "t1-second-inserted")
    eng.at(0.5, fired.append, "t0.5")
    n = eng.run()
    assert n == 5
    assert fired == [
        "t0.5", "t1-first-inserted", "t1-second-inserted",
        "t1-late-priority", "t2",
    ]
    assert eng.now == 2.0


def test_engine_time_is_monotonic_and_rejects_the_past():
    eng = Engine()
    seen = []
    eng.at(1.0, lambda: seen.append(eng.now))
    eng.at(3.0, lambda: seen.append(eng.now))
    eng.run()
    assert seen == [1.0, 3.0]
    with pytest.raises(SimTimeError):
        eng.at(2.0, lambda: None)       # now is 3.0
    with pytest.raises(SimTimeError):
        eng.schedule(-0.1, lambda: None)
    with pytest.raises(SimTimeError):
        eng.at(math.inf, lambda: None)


def test_engine_run_until_and_cancel():
    eng = Engine()
    fired = []
    eng.at(1.0, fired.append, "a")
    ev = eng.at(2.0, fired.append, "cancelled")
    eng.at(5.0, fired.append, "late")
    ev.cancel()
    eng.run(until=3.0)
    assert fired == ["a"]
    assert eng.now == 3.0               # advances to the horizon
    eng.run()
    assert fired == ["a", "late"]


def test_linkpool_contention_and_unlimited():
    pool = LinkPool(1)
    s1, e1 = pool.acquire(0.0, 2.0)
    s2, e2 = pool.acquire(0.0, 2.0)
    assert (s1, e1) == (0.0, 2.0)
    assert (s2, e2) == (2.0, 4.0)       # queued behind the single link
    two = LinkPool(2)
    assert two.acquire(0.0, 2.0) == (0.0, 2.0)
    assert two.acquire(0.0, 2.0) == (0.0, 2.0)   # second link, no wait
    unlimited = LinkPool(0)
    for _ in range(4):
        assert unlimited.acquire(1.0, 2.0) == (1.0, 3.0)


def test_cluster_transfer_respects_tier_degree():
    topo = tpu_v5e_cluster(2).with_shape((2, 2), degree=1)
    eng = Engine()
    cl = SimCluster(eng, topo)
    # both cross-machine transfers leave machine 0: one egress link
    dur = topo.tiers[-1].transfer_time(1024.0) + topo.assemble_cost
    e1 = cl.transfer(0, 2, 1024.0)
    e2 = cl.transfer(1, 3, 1024.0)
    assert e1 == pytest.approx(dur)
    assert e2 == pytest.approx(2 * dur)
    # intra-machine transfer uses the local tier, no pool contention
    e3 = cl.transfer(0, 1, 1024.0)
    assert e3 == pytest.approx(
        topo.tiers[0].transfer_time(1024.0) + topo.assemble_cost
    )


# ----------------------------------------------------------------------
# Workload determinism + shaping
# ----------------------------------------------------------------------

def test_trace_is_seed_deterministic():
    cfg = WorkloadConfig(rate=5.0, horizon=30.0, seed=7)
    a, b = generate_trace(cfg), generate_trace(cfg)
    assert a.requests == b.requests
    c = generate_trace(WorkloadConfig(rate=5.0, horizon=30.0, seed=8))
    assert c.requests != a.requests


def test_trace_lengths_are_capped_and_quantized():
    cfg = WorkloadConfig(
        rate=20.0, horizon=20.0, seed=3, mean_prompt_tokens=100,
        max_prompt_tokens=160, prompt_quantum=16, max_gen_tokens=48,
    )
    tr = generate_trace(cfg)
    assert tr.n_requests > 100
    for r in tr.requests:
        assert 1 <= r.prompt_tokens <= 160
        assert r.prompt_tokens % 16 == 0 or r.prompt_tokens == 160
        assert 1 <= r.gen_tokens <= 48
        assert 0.0 <= r.t_arrival < cfg.horizon


def test_burst_and_diurnal_shape_the_arrival_rate():
    base = dict(rate=10.0, horizon=100.0, seed=5)
    burst = generate_trace(
        WorkloadConfig(arrival="burst", burst_mult=6.0, burst_start=0.25,
                       burst_frac=0.1, **base)
    )
    window = [r for r in burst.requests if 25.0 <= r.t_arrival < 35.0]
    outside = [r for r in burst.requests if not 25.0 <= r.t_arrival < 35.0]
    rate_in = len(window) / 10.0
    rate_out = len(outside) / 90.0
    assert rate_in > 3.0 * rate_out     # 6x burst, generous noise margin
    diurnal = generate_trace(
        WorkloadConfig(arrival="diurnal", diurnal_amp=0.8,
                       diurnal_period=100.0, **base)
    )
    # first half-period rides the +sin peak, second the trough
    first = sum(1 for r in diurnal.requests if r.t_arrival < 50.0)
    second = diurnal.n_requests - first
    assert first > second


# ----------------------------------------------------------------------
# The acceptance cross-check: sim timing == core.simulator, exactly
# ----------------------------------------------------------------------

def test_single_collective_completion_equals_core_simulate():
    topo = tpu_v5e_3tier(2).with_shape((2, 4, 2))
    eng = Engine()
    cl = SimCluster(eng, topo)
    nbytes = float(1 << 20)
    done_at = []
    end = cl.run_collective(
        "all_reduce", nbytes, lambda: done_at.append(eng.now)
    )
    eng.run()
    strategy = best_plan(topo, "all_reduce", nbytes).strategy
    sched = registry.get_spec("all_reduce", strategy).build_schedule(
        topo, nbytes
    )
    assert end == core_sim.simulate(sched)          # exact, not approx
    assert done_at == [end]
    # memoized repricing stays exact and identical
    assert cl.collective_time("all_reduce", nbytes) == end


def test_collective_time_exact_for_explicit_strategies():
    topo = tpu_v5e_3tier(2).with_shape((2, 2, 2))
    cl = SimCluster(Engine(), topo)
    for strategy in ("hier_par", "hier_par_bw"):
        for nbytes in (4096.0, 1 << 18):
            spec = registry.get_spec("all_reduce", strategy)
            want = core_sim.simulate_rounds(
                spec.build_schedule(topo, float(nbytes))
            )
            got = cl.collective_time(
                "all_reduce", nbytes, strategy=strategy
            )
            assert got == want


# ----------------------------------------------------------------------
# Serving: determinism, Little's law, KV admission
# ----------------------------------------------------------------------

def test_smoke_scenario_is_deterministic_and_completes():
    a = run_scenario(get_scenario("smoke"), "sim")
    b = run_scenario(get_scenario("smoke"), "sim")
    assert a == b
    assert a["n_completed"] == a["n_requests"] > 0
    assert a["latency_p99_s"] >= a["latency_p50_s"] > 0
    assert a["ttft_p50_s"] > 0
    assert 0.0 < a["utilization"] < 1.0


def test_littles_law_on_single_slot_queue():
    """M/M/1-style sanity: with one batch slot, the time-averaged number
    in system must equal arrival rate x mean sojourn time (Little's law;
    the sim computes L and W through independent accountings)."""
    topo = tpu_v5e_cluster(2).with_shape((2, 2))
    eng = Engine()
    cl = SimCluster(eng, topo)
    sim = ServingSim(cl, ServingConfig(max_batch=1, decode_time_per_token=2e-3))
    trace = generate_trace(
        WorkloadConfig(rate=3.0, horizon=120.0, seed=11,
                       mean_prompt_tokens=32, mean_gen_tokens=8,
                       max_prompt_tokens=64, max_gen_tokens=16)
    )
    m = sim.run(trace)
    assert m["n_completed"] == m["n_requests"]
    lam = m["n_completed"] / m["span_s"]
    lw = lam * m["latency_mean_s"]
    assert m["mean_in_system"] == pytest.approx(lw, rel=1e-9)
    assert 0.0 < m["utilization"] < 1.0


def test_latency_grows_with_offered_load():
    sc = get_scenario("smoke")
    light = run_scenario(sc, "sim", rate_scale=0.25)
    heavy = run_scenario(sc, "sim", rate_scale=4.0)
    assert heavy["latency_p50_s"] > light["latency_p50_s"]
    assert heavy["utilization"] > light["utilization"]


def test_kv_capacity_gates_admission():
    topo = tpu_v5e_cluster(2).with_shape((2, 2))
    eng = Engine()
    scfg = ServingConfig(
        max_batch=8, kv_bytes_per_token=4096.0,
        # room for ~one 48-token request's shards per node, not two
        kv_capacity_bytes=4096.0 / topo.n_procs * 60,
    )
    cl = SimCluster(eng, topo, kv_capacity_bytes=scfg.kv_capacity_bytes)
    sim = ServingSim(cl, scfg)
    reqs = [
        Request(rid=0, t_arrival=0.0, prompt_tokens=40, gen_tokens=8),
        Request(rid=1, t_arrival=0.001, prompt_tokens=40, gen_tokens=8),
    ]
    cfg = WorkloadConfig(rate=1.0, horizon=1.0, seed=0)
    m = sim.run(Trace(cfg=cfg, requests=reqs))
    assert m["n_completed"] == 2
    first, second = sim.records
    # the second request's KV did not fit until the first one released
    assert second.t_admitted >= first.t_finish
    assert all(n.kv_used_bytes == 0.0 for n in cl.nodes)


def test_sim_from_calibration_json(tmp_path):
    """The sim consumes the same calibration JSON CommContext does, and
    transplants the fitted tiers onto the scenario shape."""
    calib = CalibrationResult(
        topology=tpu_v5e_3tier(2),
        measurements=(),
        rel_rmse=0.01,
        n_iterations=3,
        meta={"dispatch_cost": 2.5e-6},
    )
    p = tmp_path / "calibration.json"
    save_calibration(calib, p)
    eng = Engine()
    cl = SimCluster.from_calibration(eng, str(p), fanout=(2, 4, 2))
    assert cl.topo.n_procs == 16
    assert [t.name for t in cl.topo.tiers] == ["ici", "pcie", "dcn"]
    assert cl.topo.tiers[2].beta == pytest.approx(
        tpu_v5e_3tier(2).tiers[2].beta
    )
    m = run_scenario(get_scenario("smoke"), "sim", calibration=str(p))
    assert m["calibrated"] is True
    assert m["n_completed"] == m["n_requests"]
    # the stored dispatch fit is what overlap pricing resolves
    assert resolve_dispatch_cost(str(p)) == 2.5e-6


# ----------------------------------------------------------------------
# Per-issue dispatch cost (simulate_overlapped satellite)
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def overlap_build():
    topo = tpu_v5e_cluster(2).with_shape((4, 2))
    return lambda m: S.allreduce_hier_par_bw(topo, m, payloads=False)


def test_dispatch_cost_zero_is_the_old_model(overlap_build):
    a = core_sim.simulate_overlapped(overlap_build, 1 << 22, 8, 0.01)
    b = core_sim.simulate_overlapped(
        overlap_build, 1 << 22, 8, 0.01, dispatch_cost=0.0
    )
    assert a.t_overlapped == b.t_overlapped
    assert a.dispatch_cost == 0.0


def test_dispatch_cost_penalizes_only_the_overlapped_path(overlap_build):
    base = core_sim.simulate_overlapped(overlap_build, 1 << 22, 8, 0.01)
    taxed = core_sim.simulate_overlapped(
        overlap_build, 1 << 22, 8, 0.01, dispatch_cost=1e-3
    )
    # compute-bound regime: 8 issues x 1ms land fully on the shadow
    assert taxed.t_overlapped == pytest.approx(base.t_overlapped + 8e-3)
    assert taxed.t_serial == base.t_serial      # serial pays no dispatch
    with pytest.raises(ValueError):
        core_sim.simulate_overlapped(
            overlap_build, 1 << 22, 8, 0.01, dispatch_cost=-1.0
        )


def test_dispatch_cost_feature_decomposition_stays_exact(overlap_build):
    m, n, ct, dc = float(1 << 22), 8, 0.01, 1e-3
    cost = core_sim.simulate_overlapped(
        overlap_build, m, n, ct, dispatch_cost=dc
    )
    feats, c0 = core_sim.overlapped_cost_features(
        overlap_build, m, n, ct, dispatch_cost=dc
    )
    params = overlap_build(m).topo.param_vector()
    t = sum(f * p for f, p in zip(feats, params)) + c0
    assert t == pytest.approx(cost.t_overlapped, rel=1e-12)


def test_overlapped_time_affine_matches_simulator(overlap_build):
    stages = bucketing.stage_affine(overlap_build)
    for dc in (0.0, 5e-4):
        for n in (1, 4, 16):
            want = core_sim.simulate_overlapped(
                overlap_build, 1 << 22, n, 0.02, dispatch_cost=dc
            ).t_overlapped
            got = bucketing.overlapped_time_affine(
                stages, 1 << 22, n, 0.02, dc
            )
            assert got == pytest.approx(want, rel=1e-9)


def test_fit_dispatch_cost():
    assert core_sim.fit_dispatch_cost(0.10, 0.09, 2) == pytest.approx(5e-3)
    # measured faster than modelled -> no observable overhead
    assert core_sim.fit_dispatch_cost(0.08, 0.09, 2) == 0.0
    # the in-code constant is the LAST-RESORT fallback (no fixture, no
    # calibration): assume zero overhead rather than invent one
    assert core_sim.DEFAULT_DISPATCH_COST == 0.0
    with pytest.raises(ValueError):
        core_sim.fit_dispatch_cost(0.1, 0.1, 0)


def test_resolve_dispatch_cost_prefers_committed_fixture(monkeypatch):
    """With no calibration in play, overlap pricing resolves the committed
    BENCH_step.json fixture's fit -- the bench's measured overhead reaches
    planning defaults without any env plumbing."""
    import json
    from pathlib import Path

    from repro.comm import grad_sync
    from repro.comm.calibrate import CALIBRATION_ENV

    monkeypatch.delenv(CALIBRATION_ENV, raising=False)
    # drop the module-level cache: an earlier test may have resolved the
    # fixture before this one read the file
    monkeypatch.setattr(grad_sync, "_FIXTURE_DISPATCH", [])
    fixture = Path(__file__).resolve().parents[1] / "BENCH_step.json"
    want = json.loads(fixture.read_text())["dispatch_cost_fit_us"] * 1e-6
    assert resolve_dispatch_cost() == pytest.approx(want)


def test_large_dispatch_cost_flips_auto_overlap_to_serial():
    topo = tpu_v5e_cluster(2).with_shape((2, 2))
    kw = dict(compute_time=0.05, accum_steps=4, overlap="auto", topo=topo)
    free = plan_pod_sync(2, 1 << 24, dispatch_cost=0.0, **kw)
    taxed = plan_pod_sync(2, 1 << 24, dispatch_cost=0.05, **kw)
    assert free.overlap > 0
    assert taxed.overlap == 0           # overhead makes overlap a loss
    assert taxed.t_step <= free.t_step + 0.05 * free.accum_steps * free.overlap
    # default resolution (no calibration anywhere) is the fixture fit
    assert plan_pod_sync(2, 1 << 24, **kw) == plan_pod_sync(
        2, 1 << 24, dispatch_cost=resolve_dispatch_cost(), **kw
    )


# ----------------------------------------------------------------------
# Live engine parity (stop tokens + per-step latencies)
# ----------------------------------------------------------------------

def test_serve_engine_stop_tokens_and_step_latencies():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.config import reduced_for_smoke
    from repro.serve.engine import Engine as ServeEngine

    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32"
    )
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    eng = ServeEngine(cfg, params, max_len=32)
    free = eng.generate(prompts, 6)
    assert free.steps == 6
    assert not free.stopped_early
    assert len(free.step_latencies_s) == 5          # one per decode step
    assert all(t > 0 for t in free.step_latencies_s)
    assert free.step_p99_s >= free.step_p50_s > 0

    # greedy decode is deterministic: stopping on every token the free run
    # emitted in its first two steps must end generation by step 2
    stop = {int(t) for t in free.tokens[:, :2].reshape(-1)}
    eng2 = ServeEngine(cfg, params, max_len=32)
    stopped = eng2.generate(prompts, 6, stop_tokens=stop, pad_token=-1)
    assert stopped.stopped_early
    assert stopped.steps <= 2
    assert len(stopped.step_latencies_s) == stopped.steps - 1
    assert bool(jnp.all(stopped.tokens[:, 0] == free.tokens[:, 0]))
