"""Per-arch smoke tests: reduced configs, forward/train/serve consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.models import lm
from repro.models.config import reduced_for_smoke

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _cfg(arch):
    return reduced_for_smoke(get_config(arch)).with_(compute_dtype="float32")


def _inputs(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kwargs = {}
    if cfg.family == "encdec":
        kwargs["enc_embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model)) * 0.1
    return tokens, kwargs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = _cfg(arch)
    params = lm.init_params(KEY, cfg)
    tokens, kwargs = _inputs(cfg)
    logits, aux = jax.jit(
        lambda p, t: lm.forward(p, cfg, tokens=t, **kwargs)
    )(params, tokens)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    """One real optimizer step on the reduced config: finite loss + grads."""
    from repro.optim import adamw
    from repro.sharding import rules
    from repro.train import steps as train_steps

    cfg = _cfg(arch)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = train_steps.TrainConfig(use_kernel=False)
    step, _ = train_steps.make_train_step(
        cfg, tcfg, adamw.AdamWConfig(), mesh, rules.ShardingPolicy()
    )
    params = lm.init_params(KEY, cfg)
    opt = adamw.init_state(params)
    tokens, kwargs = _inputs(cfg)
    batch = {"tokens": tokens, "labels": tokens}
    batch.update(kwargs)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(opt2.step) == 1
    # params actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_match_forward(arch):
    """prefill(t[:-1]) then decode(t[-1]) must equal forward logits."""
    cfg = _cfg(arch)
    params = lm.init_params(KEY, cfg)
    tokens, kwargs = _inputs(cfg)
    logits, _ = jax.jit(lambda p, t: lm.forward(p, cfg, tokens=t, **kwargs))(
        params, tokens
    )
    cache = lm.init_cache(cfg, B, S + 4, enc_len=S)
    pf, cache = jax.jit(lambda p, t, c: lm.prefill(p, cfg, t, c, **kwargs))(
        params, tokens[:, : S - 1], cache
    )
    dec, _ = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c))(
        params, tokens[:, S - 1], cache
    )
    scale = float(jnp.max(jnp.abs(logits))) + 1e-9
    np.testing.assert_allclose(
        np.asarray(pf), np.asarray(logits[:, S - 2]), atol=2e-3 * scale
    )
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(logits[:, S - 1]), atol=2e-3 * scale
    )


def test_sliding_window_ring_cache_decode():
    """Hybrid arch with window smaller than context: ring cache decode must
    match a full-cache decode restricted to the window."""
    cfg = _cfg("zamba2_2_7b").with_(sliding_window=8)
    params = lm.init_params(KEY, cfg)
    T = 24
    tokens = jax.random.randint(KEY, (1, T), 0, cfg.vocab_size)
    # decode token-by-token from scratch with ring cache
    cache = lm.init_cache(cfg, 1, T)  # kv_len = window = 8
    assert cache["k"].shape[2] == 8
    logits_ring = []
    for t in range(T):
        lg, cache = lm.decode_step(params, cfg, tokens[:, t], cache)
        logits_ring.append(lg)
    # reference: full forward with the same window
    full, _ = lm.forward(params, cfg, tokens=tokens)
    got = np.asarray(jnp.stack(logits_ring, 1))
    want = np.asarray(full)
    scale = np.abs(want).max()
    np.testing.assert_allclose(got, want, atol=3e-3 * scale)


def test_param_count_analytic_close_to_actual():
    """Analytic 6ND accounting stays within 10% of real param counts."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        actual = sum(
            int(np.prod(s.shape))
            for s in jax.tree.leaves(
                jax.eval_shape(lambda c=cfg: lm.init_params(KEY, c))
            )
        )
        est = cfg.param_count()
        assert abs(est - actual) / actual < 0.10, (arch, est, actual)


@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_shape_applicability_rules(shape_name):
    shape = SHAPES[shape_name]
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, shape)
        if shape_name == "long_500k":
            assert ok == cfg.sub_quadratic
            if not ok:
                assert "full-attention" in why
        else:
            assert ok
