"""End-to-end system behaviour: the full stack wired together.

Covers: config registry -> model init -> sharded train step -> data
pipeline -> loop with checkpointing -> serving hand-off; plus the dry-run
entry points at test scale.
"""

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, ARCH_IDS, all_cells, get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import lm
from repro.models.config import reduced_for_smoke
from repro.optim import adamw
from repro.sharding import rules
from repro.train import loop as train_loop
from repro.train import steps as train_steps

REPO = Path(__file__).resolve().parent.parent


def test_registry_covers_all_assigned_archs():
    assert len(ARCH_IDS) == 10
    for alias in ALIASES:
        cfg = get_config(alias)
        assert cfg.name == alias
    cells = list(all_cells())
    assert len(cells) == 40                      # 10 archs x 4 shapes
    runnable = [c for c in cells if c[3]]
    assert len(runnable) == 32                   # 8 archs skip long_500k


def test_assigned_dims_match_assignment():
    c = get_config("grok-1-314b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.n_experts_per_tok) == (
        64, 6144, 48, 8, 32768, 131072, 8, 2)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_experts, c.n_experts_per_tok, c.n_shared_experts) == (60, 4, 4)
    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get_config("rwkv6-1.6b")
    assert c.attention_free and c.d_ff == 7168
    c = get_config("qwen2-vl-72b")
    assert c.mrope and c.n_layers == 80


def test_end_to_end_train_then_serve(tmp_path):
    """Train a tiny model for 30 steps (loss must drop), checkpoint it,
    restore into a serving process, and greedily decode."""
    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tcfg = train_steps.TrainConfig(use_kernel=False)
    step, _ = train_steps.make_train_step(
        cfg, tcfg, adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=30),
        mesh, rules.ShardingPolicy())
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                    global_batch=4, seed=11))
    state = train_loop.run(
        jax.jit(step), params, opt, data,
        train_loop.LoopConfig(total_steps=30, ckpt_every=30,
                              ckpt_dir=str(tmp_path), log_every=100))
    assert state.losses[-1] < state.losses[0]

    # restore into serving
    from repro.checkpoint.checkpointer import Checkpointer
    ck = Checkpointer(tmp_path)
    (restored, _), _ = ck.restore((state.params, state.opt_state))
    prompts = jnp.zeros((2, 8), jnp.int32)
    cache = lm.init_cache(cfg, 2, 16)
    logits, cache = lm.prefill(restored, cfg, prompts, cache)
    tok = jnp.argmax(logits, -1)
    for _ in range(4):
        logits, cache = lm.decode_step(restored, cfg, tok, cache)
        tok = jnp.argmax(logits, -1)
    assert tok.shape == (2,)
    assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))


def test_dryrun_module_runs_smallest_cell(tmp_path):
    """The real dry-run entry point, as a subprocess (its own device flag),
    on the smallest cell -- proves the launcher wiring end to end."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "llama3.2-1b", "--shape", "decode_32k",
         "--mesh", "single", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd=str(REPO),
    )
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads((tmp_path / "llama3.2-1b_decode_32k_single.json").read_text())
    assert rec["ok"]
    assert rec["collectives"]["n_ops"] > 0
    assert rec["memory"]["peak_per_device_bytes"] < 16 * 2**30


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint written under one mesh restores onto another (elastic)."""
    from repro.checkpoint.checkpointer import Checkpointer, elastic_reshard
    from jax.sharding import PartitionSpec as P

    tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(4, 8)}
    ck = Checkpointer(tmp_path)
    ck.save(1, tree, blocking=True)
    restored, _ = ck.restore(tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    placed = elastic_reshard(restored, mesh, {"w": P("data", "model")})
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.asarray(tree["w"]))
