"""Sequence-parallel ring attention vs full attention (8 fake devices)."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_ring_attention_matches_full():
    body = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.kernels.flash_attention import ref
        from repro.sharding.ring_attention import ring_attention

        mesh = jax.make_mesh((8,), ("data",))
        rng = np.random.RandomState(0)
        for (B, S, H, Hkv, Dh, causal) in [
            (2, 128, 4, 2, 16, True),
            (1, 64, 2, 2, 32, True),
            (2, 128, 4, 1, 16, False),
        ]:
            q = jnp.asarray(rng.randn(B, S, H, Dh), jnp.float32)
            k = jnp.asarray(rng.randn(B, S, Hkv, Dh), jnp.float32)
            v = jnp.asarray(rng.randn(B, S, Hkv, Dh), jnp.float32)
            got = ring_attention(q, k, v, mesh, "data", causal=causal)
            want = ref.mha_reference(q, k, v, causal=causal)
            err = float(jnp.max(jnp.abs(got - want)))
            assert err < 3e-5, (B, S, H, Hkv, Dh, causal, err)
            print("ring ok", B, S, H, Hkv, Dh, causal, err)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
