"""End-to-end training driver: a ~100M-parameter llama-style model on the
synthetic pipeline with checkpointing and resume.

The same launcher scales to the production mesh (launch/train.py
--production-mesh); reduced dims keep this demo CPU-sized.  Use --steps to
train longer; --d-model 768 --layers 12 gives the full ~100M config.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 60]
"""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
steps = "60"
if "--steps" in sys.argv:
    steps = sys.argv[sys.argv.index("--steps") + 1]
size = ["--d-model", "256", "--layers", "4"]
if "--full" in sys.argv:  # the real ~100M run (slow on 1 CPU core)
    size = ["--d-model", "768", "--layers", "12"]

subprocess.run(
    [sys.executable, "-m", "repro.launch.train",
     "--arch", "llama3.2-1b", "--reduced", "--steps", steps,
     "--global-batch", "8", "--seq", "256",
     "--ckpt-dir", "/tmp/repro_example_ckpt", "--ckpt-every", "25",
     *size],
    env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
         "HOME": "/root"},
    check=True,
)
print("\nRe-running resumes from the newest checkpoint (fault tolerance);\n"
      "try killing it mid-run and re-launching.")
