"""Beyond-paper feature demo: int8-compressed DCN gradient sync.

Trains the same tiny model twice on 8 fake devices (2 pods x 2 data x 2
model) -- once with full-precision pod sync, once with q8 -- and compares
loss curves: the compressed run tracks the exact one while moving ~4x
fewer bytes across the pod tier (the dry-run HLO in EXPERIMENTS.md
quantifies the wire savings at production scale).

Run:  PYTHONPATH=src python examples/gradient_compression.py
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
body = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.models import lm
from repro.models.config import reduced_for_smoke
from repro.optim import adamw
from repro.sharding import rules
from repro.train import steps as T

cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(compute_dtype="float32")
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
from repro import comm
from repro.core.topology import V5E_CHIPS_PER_POD
print("cost model pick for this model's DCN tier (pod_sync='auto'):",
      comm.select_pod_sync(2, cfg.param_count() * 4.0 / V5E_CHIPS_PER_POD))
data = make_pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                global_batch=8, seed=5))
for sync in ["flat", "q8"]:
    tcfg = T.TrainConfig(pod_mode="manual", pod_sync=sync, use_kernel=False)
    step, bspecs = T.make_train_step(cfg, tcfg, adamw.AdamWConfig(lr=3e-3),
                                     mesh, rules.ShardingPolicy())
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    mesh_ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh
    with mesh_ctx:
        n = lambda s: jax.tree.map(lambda sp: NamedSharding(mesh, sp), s,
                                   is_leaf=lambda x: isinstance(x, P))
        jstep = jax.jit(step)
        losses = []
        for i in range(30):
            b = jax.device_put(data.batch(i), n(bspecs))
            params, opt, m = jstep(params, opt, b)
            losses.append(float(m["loss"]))
    print(f"pod_sync={sync:4s}  loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
          f"(last5 mean {np.mean(losses[-5:]):.3f})")
print("q8 tracks flat while crossing the DCN tier with ~1/4 the bytes.")
"""
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
env["PYTHONPATH"] = str(REPO / "src")
subprocess.run([sys.executable, "-c", textwrap.dedent(body)], env=env, check=True)
