"""Batched serving example: prefill + greedy decode with the sharded
KV-cache machinery (the decode_32k / long_500k dry-run cells use the same
serve_step)."""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
arch = sys.argv[1] if len(sys.argv) > 1 else "zamba2-2.7b"

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve",
     "--arch", arch, "--reduced", "--batch", "4",
     "--prompt-len", "64", "--gen", "24"],
    env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin",
         "HOME": "/root"},
    check=True,
)
