"""Quickstart: the paper's contribution in 60 lines.

Builds the two-tier cluster model, compares collective schedules under it,
lets the planner pick, and shows the decision changing with message size
and topology -- the whole point of Task & Chauhan's model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro import comm  # noqa: E402
from repro.core import paper_smp_cluster, tpu_v5e_cluster  # noqa: E402
from repro.core.schedules import build  # noqa: E402
from repro.core.simulator import evaluate  # noqa: E402

# ----------------------------------------------------------------------
# 1. A 2008-style cluster: 8 machines x 4 cores, 2 NICs each.
# ----------------------------------------------------------------------
topo = paper_smp_cluster(n_machines=8, cores=4, nics=2)
print("== broadcast on an 8x4 SMP cluster (64 KiB) ==")
for strat in ["flat", "hier_seq", "hier_par"]:
    r = evaluate(build(topo, "broadcast", strat, 64 * 1024))
    print(f"  {strat:10s} rounds={r.n_rounds:3d} t={r.t_rounds*1e6:8.1f}us "
          f"global_bytes={r.global_bytes/1e3:8.1f}kB")

# ----------------------------------------------------------------------
# 2. The paper's C2: gather is NOT inverse broadcast.
# ----------------------------------------------------------------------
bc = evaluate(build(topo, "broadcast", "hier_par", 64 * 1024))
ga = evaluate(build(topo, "gather", "hier_par", 64 * 1024))
print(f"\n== C2 asymmetry ==\n  broadcast: {bc.n_rounds} rounds; "
      f"gather: {ga.n_rounds} rounds (reads are not writes)")

# ----------------------------------------------------------------------
# 3. The registry-backed planner on the production TPU topology
#    (2 pods x 256 chips): CommContext.plan returns a *callable* plan.
# ----------------------------------------------------------------------
ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
print("\n== planner decisions, all_reduce on 2x256 TPU ==")
for nbytes in [1e4, 1e6, 1e9]:
    pc = ctx.plan("all_reduce", nbytes, lossy_ok=True)
    flat = next(p.plan for p in ctx.plans("all_reduce", nbytes, lossy_ok=True)
                if p.plan.strategy == "flat")
    best = pc.plan
    print(f"  {nbytes:9.0e} B -> {best.strategy:15s} "
          f"{best.t_rounds*1e3:9.3f}ms  (flat: {flat.t_rounds*1e3:9.3f}ms, "
          f"{flat.t_rounds/best.t_rounds:4.1f}x slower)  "
          f"impl={best.impl}")

# ----------------------------------------------------------------------
# 4. Every registered strategy, costed: the cost table behind the choice.
#    'executable=False' rows are model-only strawmen (e.g. the single-
#    leader hier_seq) -- the registry guarantees every other row can run.
# ----------------------------------------------------------------------
print("\n== cost table, broadcast of 64 KiB on the TPU topology ==")
for row in ctx.cost_table("broadcast", 64 * 1024):
    run = "runnable " if row["executable"] else "model-only"
    print(f"  {row['strategy']:10s} [{run}] t={row['t_us']:9.1f}us "
          f"rounds={row['n_rounds']:3d} global={row['global_bytes']/1e3:.1f}kB")

print("\nA PlannedCollective is directly callable inside a shard_map region "
      "over a (mach, core) mesh -- the same objects the trainer executes "
      "(repro/comm/impls.py); see tests/test_collectives_multidevice.py.")
