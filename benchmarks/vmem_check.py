"""Structural VMEM budgeting for the Pallas kernels (no hardware needed).

For each kernel and each production shape it will face, compute the VMEM
working set implied by the BlockSpecs (inputs + outputs + scratch per grid
step, double-buffered) and check it against the ~16 MiB v5e VMEM budget.
This is the dry-run analogue of a VMEM OOM check, and documents why the
default block shapes are what they are (MXU-aligned 128-multiples).
"""

from __future__ import annotations

VMEM_BYTES = 16 * 2**20          # v5e VMEM per core
DB = 2                           # double buffering factor for HBM->VMEM


def flash_attention_vmem(bq=128, bk=128, dh=128, dtype_bytes=2):
    q = bq * dh * dtype_bytes
    k = bk * dh * dtype_bytes
    v = bk * dh * dtype_bytes
    o = bq * dh * dtype_bytes
    scratch = bq * 1 * 4 * 2 + bq * dh * 4     # m, l (f32) + acc (f32)
    logits = bq * bk * 4                        # transient [BQ, BK] f32
    total = DB * (q + k + v + o) + scratch + logits
    return total


def rmsnorm_vmem(rows=256, d=8192, dtype_bytes=2):
    return DB * (2 * rows * d * dtype_bytes) + d * 4


def ssd_vmem(q=128, p=64, n=64, dtype_bytes=4):
    x = q * p * dtype_bytes
    bc = 2 * q * n * dtype_bytes
    dt = 2 * q * dtype_bytes
    o = q * p * dtype_bytes
    scratch = n * p * 4
    seg = q * q * 4                              # [Q,Q] decay matrix f32
    return DB * (x + bc + dt + o) + scratch + seg


def rows():
    out = []
    # attention blocks across the assigned head dims (64..128 padded to 128)
    for bq, bk, dh in [(128, 128, 128), (256, 256, 128), (512, 512, 128),
                       (128, 128, 256)]:
        b = flash_attention_vmem(bq, bk, dh)
        out.append((f"flash_bq{bq}_bk{bk}_dh{dh}", b / 2**10,
                    f"fits={b < VMEM_BYTES};frac={b/VMEM_BYTES:.3f}"))
    # rmsnorm across the assigned d_models (adaptive row blocks: the kernel
    # caps block_rows so the working set stays within ~half of VMEM)
    for d in [2048, 3072, 4096, 6144, 8192]:
        rows_adaptive = min(256, max(8, (1 << 23) // (8 * d)))
        b = rmsnorm_vmem(rows_adaptive, d)
        out.append((f"rmsnorm_rows{rows_adaptive}_d{d}", b / 2**10,
                    f"fits={b < VMEM_BYTES};frac={b/VMEM_BYTES:.3f}"))
    # ssd scan: zamba2 heads (P=64, N=64) at various chunks
    for q in [64, 128, 256]:
        b = ssd_vmem(q)
        out.append((f"ssd_chunk{q}_p64_n64", b / 2**10,
                    f"fits={b < VMEM_BYTES};frac={b/VMEM_BYTES:.3f}"))
    return out


def main():
    print("kernel_block,KiB,derived")
    bad = 0
    for name, kib, derived in rows():
        print(f"{name},{kib:.1f},{derived}")
        if "fits=False" in derived:
            bad += 1
    assert bad == 0, f"{bad} block configurations exceed VMEM"
    print(f"# all block configurations fit in {VMEM_BYTES/2**20:.0f} MiB VMEM")


if __name__ == "__main__":
    main()
