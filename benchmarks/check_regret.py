"""CI gate on cost-model strategy-selection regret (BENCH_comm.json).

``collective_bench`` records, per (collective, nbytes, shape) bucket, the
regret of the model-chosen strategy: measured time of the model's pick over
the best measured time (1.0 = the model chose optimally).  This script
distils that into a small persisted summary (``--summary-out``) so the
bench job's artifacts track regret across commits, and FAILS when the
fitted model's choices regress beyond the thresholds -- the first step of
the ROADMAP's "crossover-driven strategy pruning" trajectory.

CPU fake-device timings are dispatch-noise-dominated, so the default
thresholds are deliberately loose: they catch "the planner now picks a
strategy that is measurably, repeatedly worse", not microsecond jitter.

    python benchmarks/check_regret.py BENCH_comm.json \\
        --summary-out BENCH_regret.json

``--serve-artifact BENCH_serve.json`` additionally gates the simulated
serving trajectory (``serve_bench --smoke``): the smoke scenario must
complete every request and its p99 latency must stay within
``--max-p99-ratio`` of the artifact's unloaded single-request baseline.
The simulator is seeded and wall-clock-free, so a breach is a genuine
cost-model or serving-loop regression, not noise.

``--fault-artifact BENCH_fault.json`` gates the fault-tolerance loop
(``fault_bench --smoke``): the kill_recovery scenario must record a
completed recovery (watchdog detection -> shrunk-topology re-plan ->
restore -> resume, every request served), and re-planning collectives on
a degraded topology must never price worse than keeping the stale
healthy plan (re-plan regret <= 0).
"""

from __future__ import annotations

import argparse
import json
import sys


def evaluate(artifact: dict, max_mean_regret: float,
             max_single_regret: float) -> tuple[dict, list[str]]:
    crossover = artifact.get("crossover", [])
    summary = artifact.get("summary", {})
    mean_regret = summary.get(
        "mean_regret",
        sum(r["regret"] for r in crossover) / max(len(crossover), 1),
    )
    max_regret = max((r["regret"] for r in crossover), default=1.0)
    worst = max(crossover, key=lambda r: r["regret"], default=None)
    out = dict(
        n_buckets=len(crossover),
        mean_regret=mean_regret,
        max_regret=max_regret,
        crossover_agreement=summary.get("crossover_agreement"),
        worst_bucket=(
            dict(
                collective=worst["collective"],
                nbytes=worst["nbytes"],
                shape=worst.get("shape"),
                modelled_best=worst["modelled_best"],
                measured_best=worst["measured_best"],
                regret=worst["regret"],
            )
            if worst
            else None
        ),
        thresholds=dict(
            max_mean_regret=max_mean_regret,
            max_single_regret=max_single_regret,
        ),
    )
    failures = []
    if not crossover:
        failures.append("no crossover rows in artifact")
    if mean_regret > max_mean_regret:
        failures.append(
            f"mean regret {mean_regret:.3f} > {max_mean_regret:.3f}"
        )
    if max_regret > max_single_regret:
        failures.append(
            f"max regret {max_regret:.3f} > {max_single_regret:.3f} "
            f"(worst: {out['worst_bucket']})"
        )
    return out, failures


def evaluate_serve(artifact: dict, max_p99_ratio: float) -> tuple[dict, list[str]]:
    """Gate the BENCH_serve.json smoke scenario: full completion + bounded
    p99 tail over the unloaded single-request baseline."""
    baseline = artifact.get("baseline_latency_s")
    p99 = artifact.get("smoke_p99_s")
    ratio = artifact.get("smoke_p99_over_baseline")
    smoke_rows = [
        r for r in artifact.get("scenarios", [])
        if r.get("scenario") == "smoke"
    ]
    out = dict(
        baseline_latency_s=baseline,
        smoke_p99_s=p99,
        smoke_p99_over_baseline=ratio,
        max_p99_ratio=max_p99_ratio,
        n_smoke_points=len(smoke_rows),
    )
    failures = []
    if not smoke_rows:
        failures.append("no smoke scenario rows in serve artifact")
    for r in smoke_rows:
        if r.get("n_completed") != r.get("n_requests"):
            failures.append(
                f"smoke x{r.get('rate_scale')}: only {r.get('n_completed')}"
                f"/{r.get('n_requests')} requests completed"
            )
    if ratio is None:
        failures.append("serve artifact has no smoke_p99_over_baseline "
                        "(run serve_bench with rate scale 1.0)")
    elif ratio > max_p99_ratio:
        failures.append(
            f"smoke p99 {p99 * 1e3:.1f}ms is {ratio:.2f}x the unloaded "
            f"baseline {baseline * 1e3:.1f}ms (limit {max_p99_ratio:.2f}x)"
        )
    return out, failures


def evaluate_fault(artifact: dict,
                   max_replan_regret: float = 1e-9) -> tuple[dict, list[str]]:
    """Gate the BENCH_fault.json artifact: the kill_recovery scenario's
    full detect -> shrink -> re-plan -> restore -> resume loop must
    complete (every request served, at least one recorded recovery), and
    re-planning on a degraded topology must never price worse than keeping
    the stale healthy plan (regret <= 0 within float tolerance)."""
    kill = artifact.get("kill_recovery")
    replan = artifact.get("replan_regret", [])
    max_regret = max((r["regret"] for r in replan), default=0.0)
    worst = max(replan, key=lambda r: r["regret"], default=None)
    out = dict(
        kill_recovery=kill,
        n_replan_rows=len(replan),
        max_replan_regret=max_regret,
        n_plan_flips=sum(1 for r in replan if r.get("flipped")),
        max_replan_regret_limit=max_replan_regret,
        worst_replan=(
            dict(
                degradation=worst["degradation"],
                nbytes=worst["nbytes"],
                strategy_stale=worst["strategy_stale"],
                strategy_replanned=worst["strategy_replanned"],
                regret=worst["regret"],
            ) if worst else None
        ),
    )
    failures = []
    if kill is None:
        failures.append("no kill_recovery scenario in fault artifact")
    else:
        if kill.get("n_recoveries", 0) < 1:
            failures.append(
                "kill_recovery recorded no recovery (watchdog never "
                "detected the node loss?)"
            )
        elif kill.get("recovery_time_s", 0.0) <= 0.0:
            failures.append(
                f"kill_recovery recovery_time_s="
                f"{kill.get('recovery_time_s')} (recovery never finished)"
            )
        if kill.get("n_completed") != kill.get("n_requests"):
            failures.append(
                f"kill_recovery completed only {kill.get('n_completed')}"
                f"/{kill.get('n_requests')} requests after the node loss"
            )
    if not replan:
        failures.append("no replan_regret rows in fault artifact")
    if max_regret > max_replan_regret:
        failures.append(
            f"replan regret {max_regret:+.4f} > 0: re-planning on the "
            f"degraded topology priced WORSE than the stale plan "
            f"(worst: {out['worst_replan']})"
        )
    return out, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("artifact", help="BENCH_comm.json from collective_bench")
    ap.add_argument("--max-mean-regret", type=float, default=2.0,
                    help="fail when mean regret across crossover buckets "
                         "exceeds this factor")
    ap.add_argument("--max-single-regret", type=float, default=8.0,
                    help="fail when any single bucket's regret exceeds "
                         "this factor")
    ap.add_argument("--summary-out", default="",
                    help="also persist the regret summary JSON here")
    ap.add_argument("--serve-artifact", default="",
                    help="BENCH_serve.json from serve_bench: also gate the "
                         "smoke scenario's p99 latency")
    ap.add_argument("--max-p99-ratio", type=float, default=4.0,
                    help="fail when the smoke scenario's p99 latency "
                         "exceeds this multiple of the unloaded baseline")
    ap.add_argument("--fault-artifact", default="",
                    help="BENCH_fault.json from fault_bench: also gate the "
                         "kill_recovery loop completing and the degraded-"
                         "topology re-plan regret staying <= 0")
    ap.add_argument("--max-replan-regret", type=float, default=1e-9,
                    help="fail when re-planning on a degraded topology "
                         "prices worse than the stale plan by more than "
                         "this (regret is <= 0 for a consistent planner)")
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        artifact = json.load(f)
    out, failures = evaluate(
        artifact, args.max_mean_regret, args.max_single_regret
    )
    if args.serve_artifact:
        with open(args.serve_artifact) as f:
            serve_artifact = json.load(f)
        serve_out, serve_failures = evaluate_serve(
            serve_artifact, args.max_p99_ratio
        )
        out["serve"] = serve_out
        failures.extend(serve_failures)
        print(
            f"[regret] serve smoke p99/baseline="
            f"{serve_out['smoke_p99_over_baseline']} "
            f"(limit {args.max_p99_ratio:g})"
        )
    if args.fault_artifact:
        with open(args.fault_artifact) as f:
            fault_artifact = json.load(f)
        fault_out, fault_failures = evaluate_fault(
            fault_artifact, args.max_replan_regret
        )
        out["fault"] = fault_out
        failures.extend(fault_failures)
        kill = fault_out["kill_recovery"] or {}
        print(
            f"[regret] fault kill_recovery: "
            f"{kill.get('n_recoveries', 0)} recoveries in "
            f"{kill.get('recovery_time_s', 0.0):.3f}s, replan "
            f"{','.join(kill.get('plan_flips', [])) or 'none'}; "
            f"max replan regret {fault_out['max_replan_regret']:+.4f} "
            f"({fault_out['n_plan_flips']} flips)"
        )
    if args.summary_out:
        with open(args.summary_out, "w") as f:
            json.dump(out, f, indent=2)
    print(
        f"[regret] {out['n_buckets']} buckets "
        f"mean={out['mean_regret']:.3f} max={out['max_regret']:.3f} "
        f"agreement={out['crossover_agreement']}"
    )
    if failures:
        for msg in failures:
            print(f"[regret] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[regret] ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
