"""CI gate on cost-model strategy-selection regret (BENCH_comm.json).

``collective_bench`` records, per (collective, nbytes, shape) bucket, the
regret of the model-chosen strategy: measured time of the model's pick over
the best measured time (1.0 = the model chose optimally).  This script
distils that into a small persisted summary (``--summary-out``) so the
bench job's artifacts track regret across commits, and FAILS when the
fitted model's choices regress beyond the thresholds -- the first step of
the ROADMAP's "crossover-driven strategy pruning" trajectory.

CPU fake-device timings are dispatch-noise-dominated, so the default
thresholds are deliberately loose: they catch "the planner now picks a
strategy that is measurably, repeatedly worse", not microsecond jitter.

    python benchmarks/check_regret.py BENCH_comm.json \\
        --summary-out BENCH_regret.json
"""

from __future__ import annotations

import argparse
import json
import sys


def evaluate(artifact: dict, max_mean_regret: float,
             max_single_regret: float) -> tuple[dict, list[str]]:
    crossover = artifact.get("crossover", [])
    summary = artifact.get("summary", {})
    mean_regret = summary.get(
        "mean_regret",
        sum(r["regret"] for r in crossover) / max(len(crossover), 1),
    )
    max_regret = max((r["regret"] for r in crossover), default=1.0)
    worst = max(crossover, key=lambda r: r["regret"], default=None)
    out = dict(
        n_buckets=len(crossover),
        mean_regret=mean_regret,
        max_regret=max_regret,
        crossover_agreement=summary.get("crossover_agreement"),
        worst_bucket=(
            dict(
                collective=worst["collective"],
                nbytes=worst["nbytes"],
                shape=worst.get("shape"),
                modelled_best=worst["modelled_best"],
                measured_best=worst["measured_best"],
                regret=worst["regret"],
            )
            if worst
            else None
        ),
        thresholds=dict(
            max_mean_regret=max_mean_regret,
            max_single_regret=max_single_regret,
        ),
    )
    failures = []
    if not crossover:
        failures.append("no crossover rows in artifact")
    if mean_regret > max_mean_regret:
        failures.append(
            f"mean regret {mean_regret:.3f} > {max_mean_regret:.3f}"
        )
    if max_regret > max_single_regret:
        failures.append(
            f"max regret {max_regret:.3f} > {max_single_regret:.3f} "
            f"(worst: {out['worst_bucket']})"
        )
    return out, failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("artifact", help="BENCH_comm.json from collective_bench")
    ap.add_argument("--max-mean-regret", type=float, default=2.0,
                    help="fail when mean regret across crossover buckets "
                         "exceeds this factor")
    ap.add_argument("--max-single-regret", type=float, default=8.0,
                    help="fail when any single bucket's regret exceeds "
                         "this factor")
    ap.add_argument("--summary-out", default="",
                    help="also persist the regret summary JSON here")
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        artifact = json.load(f)
    out, failures = evaluate(
        artifact, args.max_mean_regret, args.max_single_regret
    )
    if args.summary_out:
        with open(args.summary_out, "w") as f:
            json.dump(out, f, indent=2)
    print(
        f"[regret] {out['n_buckets']} buckets "
        f"mean={out['mean_regret']:.3f} max={out['max_regret']:.3f} "
        f"agreement={out['crossover_agreement']}"
    )
    if failures:
        for msg in failures:
            print(f"[regret] FAIL: {msg}", file=sys.stderr)
        return 1
    print("[regret] ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
