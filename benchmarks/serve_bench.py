"""Traffic-scale serving benchmark: the BENCH_serve.json artifact.

Sweeps offered load over the named ``repro.sim`` scenarios and records
throughput and latency percentiles per (scenario, rate_scale) point, so the
serving trajectory is tracked across commits exactly like BENCH_comm /
BENCH_step.  The simulator prices every step's tensor-parallel collective
with the exact round model on the (optionally calibrated) 3-tier topology,
making these numbers a function of the repo's own cost model -- a planner
or model regression moves them deterministically (seeded workloads, no
wall-clock reads).

    python -m benchmarks.serve_bench --smoke --out BENCH_serve.json
    python -m benchmarks.serve_bench --calibration calibration.json

The artifact also records the smoke scenario's unloaded single-request
latency as ``baseline_latency_s``; ``check_regret.py --serve-artifact``
gates CI on the smoke p99 staying within a factor of it.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

SMOKE_SCALES = [0.5, 1.0]
FULL_SCALES = [0.5, 1.0, 2.0]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="smoke scenario only, short sweep (the CI mode)")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated scenario names (default: all)")
    ap.add_argument("--rate-scales", default="",
                    help="comma-separated offered-load multipliers")
    ap.add_argument("--calibration", default="",
                    help="calibration JSON for the link tiers (same loader "
                         "as CommContext.from_calibration)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    from repro.sim import SCENARIOS, get_scenario, run_scenario, unloaded_latency

    if args.scenarios:
        names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    elif args.smoke:
        names = ["smoke"]
    else:
        names = sorted(SCENARIOS)
    if args.rate_scales:
        scales = [float(s) for s in args.rate_scales.split(",")]
    else:
        scales = SMOKE_SCALES if args.smoke else FULL_SCALES
    calibration = args.calibration or None

    rows = []
    smoke_row = None
    for name in names:
        sc = get_scenario(name)
        for scale in scales:
            m = run_scenario(
                sc, "sim", calibration=calibration, rate_scale=scale
            )
            rows.append(m)
            if name == "smoke" and scale == 1.0:
                smoke_row = m
            print(
                f"[serve_bench] {name} x{scale:g}: "
                f"{m['n_completed']}/{m['n_requests']} done, "
                f"{m['throughput_rps']:.2f} rps, "
                f"p50 {m['latency_p50_s'] * 1e3:.1f}ms, "
                f"p99 {m['latency_p99_s'] * 1e3:.1f}ms"
            )

    baseline = unloaded_latency(get_scenario("smoke"), calibration)
    artifact = dict(
        bench="serve_sim",
        smoke=args.smoke,
        calibrated=calibration is not None,
        scenarios=rows,
        baseline_latency_s=baseline,
        smoke_p99_s=(
            smoke_row["latency_p99_s"] if smoke_row is not None else None
        ),
        smoke_p99_over_baseline=(
            smoke_row["latency_p99_s"] / baseline
            if smoke_row is not None and baseline else None
        ),
    )
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)
    print(f"[serve_bench] {len(rows)} points -> {args.out} "
          f"(baseline {baseline * 1e3:.1f}ms, smoke p99/baseline "
          f"{artifact['smoke_p99_over_baseline']})")


if __name__ == "__main__":
    main()
