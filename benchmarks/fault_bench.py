"""Fault-tolerance benchmark: the BENCH_fault.json artifact.

Runs every fault scenario twice -- once with its faults armed and once as
its ``healthy()`` twin on the identical seeded trace -- so the artifact
tracks the *cost of the fault* (p99 under fault over healthy p99, shed and
retried requests, recovery wall-clock) across commits, exactly like
BENCH_comm / BENCH_step / BENCH_serve track their trajectories.  The
``kill_recovery`` scenario exercises the full loop in-sim: node kill ->
watchdog detection -> shrunk-topology re-plan (the recorded
``plan_before``/``plan_after`` strategies flip) -> KV/state restore ->
resume.

The artifact also carries a **re-plan regret** table: for each degraded
topology (DCN brownout, node loss) it prices the healthy plan's strategy
on the degraded links against the re-planned best, per payload size.
Regret is ``(t_replanned - t_stale) / t_stale`` -- <= 0 by construction
when the planner is consistent, so ``check_regret.py --fault-artifact``
gates it at zero: a positive value means re-planning made things WORSE,
i.e. the cost model's strategy ranking broke on degraded parameters.

    python -m benchmarks.fault_bench --smoke --out BENCH_fault.json
    python -m benchmarks.fault_bench --calibration calibration.json
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

FAULT_SCENARIOS = ["kill_recovery", "brownout_burst", "straggler"]
SMOKE_SCENARIOS = ["kill_recovery"]

# payload sizes for the re-plan regret table: spans the alpha-dominated
# regime (64KB, the serving sync scale where node loss flips the
# strategy) through the beta-dominated one where bandwidth brownouts bite
REGRET_SIZES = [1 << 16, 1 << 20, 1 << 24, 1 << 27]


def _scenario_rows(names, calibration):
    from repro.sim import get_scenario, run_scenario

    rows = []
    for name in names:
        sc = get_scenario(name)
        faulted = run_scenario(sc, "sim", calibration=calibration)
        healthy = run_scenario(sc.healthy(), "sim", calibration=calibration)
        p99_ratio = (
            faulted["latency_p99_s"] / healthy["latency_p99_s"]
            if healthy["latency_p99_s"] else None
        )
        recoveries = faulted.get("recoveries", [])
        row = dict(
            scenario=name,
            fault_kinds=[f["spec"]["kind"] for f in faulted.get("faults", [])
                         if f.get("action") == "apply"],
            n_requests=faulted["n_requests"],
            n_completed=faulted["n_completed"],
            n_shed=faulted.get("n_shed", 0),
            n_retries=faulted.get("n_retries", 0),
            n_slow_steps=faulted.get("n_slow_steps", 0),
            n_recoveries=faulted.get("n_recoveries", 0),
            recovery_time_s=faulted.get("recovery_time_s", 0.0),
            recoveries=recoveries,
            latency_p50_s=faulted["latency_p50_s"],
            latency_p99_s=faulted["latency_p99_s"],
            healthy_p50_s=healthy["latency_p50_s"],
            healthy_p99_s=healthy["latency_p99_s"],
            p99_over_healthy=p99_ratio,
            throughput_rps=faulted["throughput_rps"],
            healthy_throughput_rps=healthy["throughput_rps"],
        )
        rows.append(row)
        flips = [f"{r['plan_before']}->{r['plan_after']}" for r in recoveries]
        print(
            f"[fault_bench] {name}: p99 {faulted['latency_p99_s']:.3f}s vs "
            f"healthy {healthy['latency_p99_s']:.3f}s "
            f"(x{p99_ratio:.2f}), shed={row['n_shed']} "
            f"retries={row['n_retries']} recoveries={row['n_recoveries']}"
            + (f" replan={','.join(flips)}" if flips else "")
            + (f" recovery={row['recovery_time_s']:.3f}s"
               if row['n_recoveries'] else "")
        )
    return rows


def _replan_regret_rows(calibration, fanout=(2, 4, 2), sizes=REGRET_SIZES):
    """Price stale-plan vs re-planned collectives on degraded topologies."""
    from repro.sim import Engine, SimCluster

    def cluster_for(topo=None):
        eng = Engine()
        if calibration is not None:
            cl = SimCluster.from_calibration(eng, calibration, fanout=fanout)
        else:
            cl = SimCluster.from_preset(eng, "v5e_3tier", fanout=fanout)
        if topo is not None:
            cl = SimCluster(eng, topo)
        return cl

    base = cluster_for()
    variants = [
        ("dcn_brownout",
         base.topo.degraded(tier="dcn", beta_scale=8.0, alpha_add=20e-3)),
        ("node_loss", base.topo.shrunk([0])),
    ]
    rows = []
    for label, topo in variants:
        degraded = cluster_for(topo)
        for nbytes in sizes:
            stale = base.plan_for("all_reduce", float(nbytes))
            replanned = degraded.plan_for("all_reduce", float(nbytes))
            t_stale = degraded.collective_time(
                "all_reduce", float(nbytes), strategy=stale
            )
            t_replanned = degraded.collective_time(
                "all_reduce", float(nbytes), strategy=replanned
            )
            regret = (t_replanned - t_stale) / t_stale if t_stale else 0.0
            rows.append(dict(
                degradation=label,
                collective="all_reduce",
                nbytes=nbytes,
                strategy_stale=stale,
                strategy_replanned=replanned,
                flipped=replanned != stale,
                t_stale_us=t_stale * 1e6,
                t_replanned_us=t_replanned * 1e6,
                regret=regret,
            ))
            print(
                f"[fault_bench] replan {label} {nbytes >> 10}KB: "
                f"{stale} ({t_stale * 1e6:.1f}us) -> "
                f"{replanned} ({t_replanned * 1e6:.1f}us) "
                f"regret {regret:+.3f}"
            )
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="kill_recovery scenario only (the CI mode)")
    ap.add_argument("--scenarios", default="",
                    help="comma-separated fault scenario names")
    ap.add_argument("--calibration", default="",
                    help="calibration JSON for the link tiers")
    ap.add_argument("--out", default="BENCH_fault.json")
    args = ap.parse_args(argv)

    if args.scenarios:
        names = [s.strip() for s in args.scenarios.split(",") if s.strip()]
    else:
        names = SMOKE_SCENARIOS if args.smoke else FAULT_SCENARIOS
    calibration = args.calibration or None

    rows = _scenario_rows(names, calibration)
    replan = _replan_regret_rows(calibration)

    kill = next((r for r in rows if r["scenario"] == "kill_recovery"), None)
    artifact = dict(
        bench="fault_sim",
        smoke=args.smoke,
        calibrated=calibration is not None,
        scenarios=rows,
        replan_regret=replan,
        max_replan_regret=max((r["regret"] for r in replan), default=0.0),
        n_plan_flips=sum(1 for r in replan if r["flipped"]),
        kill_recovery=(
            dict(
                n_recoveries=kill["n_recoveries"],
                recovery_time_s=kill["recovery_time_s"],
                n_completed=kill["n_completed"],
                n_requests=kill["n_requests"],
                p99_over_healthy=kill["p99_over_healthy"],
                plan_flips=[
                    f"{r['plan_before']}->{r['plan_after']}"
                    for r in kill["recoveries"]
                ],
            ) if kill else None
        ),
    )
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)
    print(
        f"[fault_bench] {len(rows)} scenarios + {len(replan)} replan rows "
        f"-> {args.out} (max replan regret "
        f"{artifact['max_replan_regret']:+.3f}, "
        f"{artifact['n_plan_flips']} flips)"
    )


if __name__ == "__main__":
    main()
