"""Benchmark runner: one function per paper table/figure plus kernel
micro-benchmarks and the roofline extraction.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract), then the
roofline table if dry-run artifacts exist.
"""

from __future__ import annotations

import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_ROOT))          # the benchmarks package itself
sys.path.insert(0, str(_ROOT / "src"))


def main() -> None:
    from benchmarks.kernel_bench import ALL_BENCHES
    from benchmarks.paper_tables import ALL_TABLES
    from benchmarks.vmem_check import rows as vmem_rows

    print("name,us_per_call,derived")
    for fn in ALL_TABLES:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")
    for fn in ALL_BENCHES:
        for name, us, derived in fn():
            print(f"{name},{us:.2f},{derived}")
    for name, kib, derived in vmem_rows():
        print(f"vmem_{name},{kib:.1f},{derived}")

    # roofline table (requires results/dryrun/*.json from launch.dryrun)
    if Path("results/dryrun").exists():
        from benchmarks import roofline

        rows = roofline.load_cells()
        done = [r for r in rows if r.get("ok")]
        if done:
            print()
            print(roofline.table(rows))


if __name__ == "__main__":
    main()
