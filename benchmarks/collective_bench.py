"""Collective micro-benchmark + calibration: the BENCH_comm.json artifact.

Times every executable registry strategy on the live device mesh across a
message-size sweep (plus a single-machine sub-mesh sweep that isolates the
local tier), fits the cost model to the measurements (``comm.calibrate``),
and writes a machine-readable trajectory artifact with measured AND modelled
times per (collective, strategy, nbytes) -- the preset model, the fitted
model, and the crossover table showing where the planner's choice matches
the empirically best strategy.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        python -m benchmarks.collective_bench --quick

(The device-count flag is auto-applied when unset, so the bare command works
on a single-CPU box too.)  ``--save-calibration`` additionally writes the
fit as a calibration JSON that ``--pod-sync auto`` / ``$REPRO_CALIBRATION``
consume.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

QUICK_SIZES = [1024.0, 16384.0, 262144.0]
FULL_SIZES = [256.0, 4096.0, 65536.0, 1048576.0, 8388608.0]


def _ensure_devices(n: int) -> None:
    """Force n fake host devices BEFORE jax initializes (no-op if set)."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}".strip()
        )


def _bench_bucketed_pod_sync(calib, repeats: int, grad_bytes: float):
    """Measure monolithic vs bucketed pod sync on the live device mesh.

    Every device plays one pod (machine = device, 1 proc, degree 1 -- the
    shape the probe mesh can actually express); each holds a synthetic
    gradient tree of ``grad_bytes`` and the four wire formats run through
    ``comm.pod_sync_grads`` monolithically and at two bucket sizes.  Rows
    pair the measured wall clock with the pipelined cost model's prediction
    on the fitted topology, so BENCH_comm.json tracks where bucketing helps
    in reality vs in the model.
    """
    import math
    import time

    import jax
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro import comm
    from repro.comm.bucketing import pipelined_time_affine, stage_affine
    from repro.comm.calibrate import calibrated_cluster

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("pod",))
    topo = calibrated_cluster(
        calib, n_machines=n, procs_per_machine=1, degree=1
    )
    elems = max(int(grad_bytes) // 4, n * 64)
    rng = np.random.RandomState(0)
    # a small tree (not one tensor) so bucketing crosses leaf boundaries
    tree = {
        "wa": rng.randn(n, elems // 2).astype(np.float32),
        "wb": rng.randn(n, elems // 4, 1).astype(np.float32),
        "wc": rng.randn(n, elems - elems // 2 - elems // 4).astype(
            np.float32
        ),
    }
    m_bytes = sum(v.nbytes for v in tree.values()) / n
    rows = []
    for fmt in comm.POD_SYNC_FORMATS:
        stages = stage_affine(comm.pod_sync_builder(topo, fmt))
        for bucket_bytes in (0, int(m_bytes) // 4, int(m_bytes) // 16):
            f = jax.jit(
                shard_map(
                    lambda g, fmt=fmt, bb=bucket_bytes: comm.pod_sync_grads(
                        g, fmt, "pod", bucket_bytes=bb
                    ),
                    mesh=mesh, in_specs=P("pod"), out_specs=P(),
                    check_rep=False,
                )
            )
            x = jax.device_put(tree)
            jax.block_until_ready(f(x))  # compile + warmup
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(f(x))
                best = min(best, time.perf_counter() - t0)
            n_chunks = (
                max(1, math.ceil(m_bytes / bucket_bytes))
                if bucket_bytes
                else 1
            )
            rows.append(
                dict(
                    fmt=fmt,
                    bucket_bytes=bucket_bytes,
                    n_chunks=n_chunks,
                    grad_bytes=m_bytes,
                    t_measured_us=best * 1e6,
                    t_model_us=pipelined_time_affine(
                        stages, m_bytes, n_chunks
                    ) * 1e6,
                )
            )
            print(
                f"[bench] pod_sync {fmt} "
                f"{'monolithic' if not bucket_bytes else f'{n_chunks} buckets'}"
                f" measured={best * 1e6:.1f}us "
                f"modelled={rows[-1]['t_model_us']:.1f}us"
            )
    return rows


def _bench_overlap_step(repeats: int, accum: int = 4):
    """Serial vs overlapped train-step wall time -> the BENCH_step artifact.

    Runs a reduced 2-layer model's manual-mode train step on a
    (2 pod x N data) fake-device mesh twice -- once serial (backward ->
    sync -> update) and once with the compute-overlapped step at the
    planner's chosen depth (forced to at least 2 so the overlapped code
    path is always exercised and measured).  The serial step's measured
    wall clock doubles as the planner's ``compute_time`` shadow (an upper
    bound: it includes the sync; on CPU fake devices the whole number is
    dispatch-noise-dominated anyway -- the artifact's value is tracking the
    serial/overlapped RATIO and the decision trajectory over time).
    """
    import dataclasses
    import math
    import time

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.config import reduced_for_smoke
    from repro.optim import adamw
    from repro.sharding import rules
    from repro.train import steps as train_steps

    cfg = reduced_for_smoke(get_config("llama3_2_1b")).with_(
        compute_dtype="float32", n_layers=2
    )
    n = len(jax.devices())
    pods = 2
    if n < 2 or n % 2:
        print(f"[bench] step bench skipped: needs an even device count "
              f"for a 2-pod mesh, have {n}")
        return None
    mesh = jax.make_mesh((pods, n // pods, 1), ("pod", "data", "model"))
    pol = rules.ShardingPolicy()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    # accum microbatches of one example per (pod, data) slot, whatever the
    # probe-mesh shape is
    B = pods * (n // pods) * accum
    tokens = jax.random.randint(
        jax.random.PRNGKey(1), (B, 32), 0, cfg.vocab_size
    )
    batch = {"tokens": tokens, "labels": tokens}

    def measure(tcfg):
        step, bspecs = train_steps.make_train_step(
            cfg, tcfg, adamw.AdamWConfig(lr=1e-3), mesh, pol
        )
        ns = lambda s: jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), s,
            is_leaf=lambda x: isinstance(x, P),
        )
        with mesh:
            jb = jax.device_put(batch, ns(bspecs))
            f = jax.jit(step)
            jax.block_until_ready(f(params, opt, jb))  # compile + warmup
            best = math.inf
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(f(params, opt, jb))
                best = min(best, time.perf_counter() - t0)
        return best

    base = train_steps.TrainConfig(
        pod_mode="manual", pod_sync="rs", accum_steps=accum,
        use_kernel=False,
    )
    t_serial = measure(base)
    print(f"[bench] train step serial: {t_serial * 1e3:.1f}ms")

    # plan overlap with the measured serial step as the compute shadow
    planned = dataclasses.replace(
        base, overlap="auto", compute_time=t_serial
    )
    decision = train_steps.plan_pod_sync(
        cfg, planned, pods, chips_per_pod=mesh.devices.size // pods
    )
    depth = max(decision.overlap, 2)   # always exercise the overlapped path
    over = dataclasses.replace(planned, overlap=depth)
    forced = train_steps.plan_pod_sync(
        cfg, over, pods, chips_per_pod=mesh.devices.size // pods
    )
    t_over = measure(over)
    print(f"[bench] train step overlapped (depth {depth}): "
          f"{t_over * 1e3:.1f}ms; auto decision: {decision.describe()}")

    rows = [
        dict(mode="serial", overlap=0, t_measured_us=t_serial * 1e6,
             t_model_us=decision.t_step_serial * 1e6),
        dict(mode="overlapped", overlap=depth, t_measured_us=t_over * 1e6,
             t_model_us=forced.t_step * 1e6),
    ]
    measured = {"serial": t_serial, "overlapped": t_over}
    chosen = "overlapped" if decision.overlap > 0 else "serial"
    t_best = min(measured.values())
    regret = (measured[chosen] - t_best) / t_best
    # one-point dispatch-cost fit: attribute the overlapped step's
    # measured-minus-modelled gap to its bucket issues (depth per sync,
    # accum syncs per step).  The fit MUST be taken against the
    # dispatch-FREE model: ``forced.t_step`` above already carries the
    # previously fitted cost (resolve_dispatch_cost reads the committed
    # fixture), so fitting against it would double-count the overhead and
    # drift the fixture upward on every regeneration.
    from repro.comm.grad_sync import resolve_dispatch_cost
    from repro.core.simulator import fit_dispatch_cost

    forced0 = train_steps.plan_pod_sync(
        cfg, over, pods, chips_per_pod=mesh.devices.size // pods,
        dispatch_cost=0.0,
    )
    n_issues = depth * accum
    dispatch_fit = fit_dispatch_cost(t_over, forced0.t_step, n_issues)
    print(f"[bench] dispatch-cost fit: {dispatch_fit * 1e6:.1f}us/issue "
          f"over {n_issues} issues "
          f"(planning default {resolve_dispatch_cost() * 1e6:.1f}us)")
    return dict(
        bench="train_step_overlap",
        arch=cfg.name,
        accum_steps=accum,
        mesh=dict(pod=pods, data=n // pods, model=1),
        dispatch_cost_fit_us=dispatch_fit * 1e6,
        dispatch_cost_used_us=resolve_dispatch_cost() * 1e6,
        dispatch_fit_n_issues=n_issues,
        rows=rows,
        decision=dict(
            fmt=decision.fmt,
            bucket_bytes=decision.bucket_bytes,
            overlap=decision.overlap,
            compute_time_us=decision.compute_time * 1e6,
            t_step_us=decision.t_step * 1e6,
            t_step_serial_us=decision.t_step_serial * 1e6,
            modelled_speedup=(
                decision.t_step_serial / decision.t_step
                if decision.t_step else 1.0
            ),
        ),
        regret=regret,
    )


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fewer sizes/repeats")
    ap.add_argument("--out", default="BENCH_comm.json")
    ap.add_argument("--mach", type=int, default=2,
                    help="machine-axis extent of the probe mesh")
    ap.add_argument("--core", type=int, default=4,
                    help="core-axis extent of the probe mesh")
    ap.add_argument("--degree", type=int, default=2,
                    help="modelled parallel-egress links per machine")
    ap.add_argument("--sizes", default="",
                    help="comma-separated per-proc byte sizes (overrides "
                         "--quick/full presets)")
    ap.add_argument("--repeats", type=int, default=0,
                    help="timing repeats per probe (0 = preset)")
    ap.add_argument("--save-calibration", default="",
                    help="also write the fitted calibration JSON here")
    ap.add_argument("--no-three-tier", action="store_true",
                    help="skip the three-tier (shm / numa / gige) probe "
                         "sweep over the same mesh")
    ap.add_argument("--step-out", default="BENCH_step.json",
                    help="serial-vs-overlapped train-step artifact path")
    ap.add_argument("--no-step-bench", action="store_true",
                    help="skip the serial-vs-overlapped train-step "
                         "measurement (BENCH_step.json)")
    args = ap.parse_args(argv)

    _ensure_devices(args.mach * args.core)
    import jax

    from repro import comm
    from repro.core.topology import paper_smp_3tier, paper_smp_cluster

    if len(jax.devices()) < args.mach * args.core:
        raise SystemExit(
            f"need {args.mach * args.core} devices, have {len(jax.devices())}"
            " (XLA_FLAGS was set before jax initialized?)"
        )
    sizes = (
        [float(s) for s in args.sizes.split(",")] if args.sizes
        else (QUICK_SIZES if args.quick else FULL_SIZES)
    )
    repeats = args.repeats or (3 if args.quick else 10)

    mesh = jax.make_mesh((args.mach, args.core), ("mach", "core"))
    preset = paper_smp_cluster(
        n_machines=args.mach, cores=args.core, nics=args.degree
    )
    print(f"[bench] probing {args.mach}x{args.core} mesh "
          f"({jax.devices()[0].platform}), sizes={sizes}, repeats={repeats}")
    calib = comm.calibrate(
        preset, mesh, sizes, repeats=repeats, verbose=True,
        meta=dict(
            quick=args.quick,
            platform=jax.devices()[0].platform,
            n_devices=len(jax.devices()),
        ),
    )
    def measurement_rows(calib_, preset_topo, tiers: int):
        ctx_f = comm.CommContext(calib_.topology)
        val_f = ctx_f.validate_against_measurements(calib_.measurements)
        val_p = comm.CommContext(preset_topo).validate_against_measurements(
            calib_.measurements
        )
        out = []
        for ms, vf, vp in zip(calib_.measurements, val_f, val_p):
            out.append(
                dict(
                    collective=ms.collective,
                    strategy=ms.strategy,
                    nbytes=ms.nbytes,
                    root=ms.root,
                    shape=list(ms.shape) if ms.shape else None,
                    fanout=list(ms.fanout) if ms.fanout else None,
                    tiers=tiers,
                    t_measured_us=ms.t_measured * 1e6,
                    t_model_preset_us=vp["t_modelled"] * 1e6,
                    t_model_fitted_us=vf["t_modelled"] * 1e6,
                    rel_error_preset=vp["rel_error"],
                    rel_error_fitted=vf["rel_error"],
                )
            )
        xo = [
            dict(r, shape=list(r["shape"]) if r["shape"] else None,
                 tiers=tiers)
            for r in ctx_f.crossover_table(calib_.measurements)
        ]
        return out, xo

    rows, crossover = measurement_rows(calib, preset, tiers=2)

    # Three-tier preset sweep over the SAME mesh: the core axis realizes
    # (cores x boards) of a shm / numa / gige hierarchy, so BENCH_comm.json
    # and the regret gate track strategy selection per network level
    # (stage-per-tier probes included).
    three_tier = None
    if not args.no_three_tier and args.core % 2 == 0 and args.core >= 4:
        preset3 = paper_smp_3tier(
            n_machines=args.mach, boards=2, cores=args.core // 2,
            nics=args.degree,
        )
        print(f"[bench] probing 3-tier {'x'.join(map(str, preset3.fanout))} "
              f"hierarchy on the same mesh")
        calib3 = comm.calibrate(
            preset3, mesh, sizes, repeats=repeats, verbose=True,
            meta=dict(quick=args.quick, tiers=3),
        )
        rows3, xo3 = measurement_rows(calib3, preset3, tiers=3)
        rows += rows3
        crossover += xo3
        prod3 = comm.plan_pod_sync(
            2, 4e9,
            topo=comm.calibrated_cluster(
                calib3, fanout=(4, 64, 2), degree=64
            ),
        )
        three_tier = dict(
            calibration=calib3.to_dict(),
            n_probes=len(rows3),
            bucketed_decision=dict(
                fmt=prod3.fmt,
                bucket_bytes=prod3.bucket_bytes,
                n_chunks=prod3.n_chunks,
                t_modelled_us=prod3.t_modelled * 1e6,
                modelled_speedup=prod3.speedup,
            ),
        )
        print(f"[bench] 3-tier production-shape auto decision: "
              f"{prod3.describe()}")

    # Bucketed-vs-monolithic pod sync on the same devices + fitted model,
    # and the production-shape decision the trainer's `auto` would take
    # with this calibration.
    bucketed = _bench_bucketed_pod_sync(
        calib, repeats, grad_bytes=max(sizes)
    )
    prod_decision = comm.plan_pod_sync(
        2, 4e9,
        topo=comm.calibrated_cluster(
            calib, n_machines=2, procs_per_machine=256, degree=64
        ),
    )
    print(f"[bench] production-shape auto decision: "
          f"{prod_decision.describe()}")

    def mean_abs(rows_, key):
        return sum(abs(r[key]) for r in rows_) / max(len(rows_), 1)

    artifact = dict(
        bench="collective_bench",
        quick=args.quick,
        calibration=calib.to_dict(),
        rows=rows,
        crossover=crossover,
        three_tier=three_tier,
        bucketed=bucketed,
        bucketed_decision=dict(
            fmt=prod_decision.fmt,
            bucket_bytes=prod_decision.bucket_bytes,
            n_chunks=prod_decision.n_chunks,
            t_modelled_us=prod_decision.t_modelled * 1e6,
            t_monolithic_us=prod_decision.t_monolithic * 1e6,
            modelled_speedup=prod_decision.speedup,
        ),
        summary=dict(
            n_probes=len(rows),
            mean_abs_rel_error_preset=mean_abs(rows, "rel_error_preset"),
            mean_abs_rel_error_fitted=mean_abs(rows, "rel_error_fitted"),
            crossover_agreement=(
                sum(r["agree"] for r in crossover) / max(len(crossover), 1)
            ),
            mean_regret=(
                sum(r["regret"] for r in crossover) / max(len(crossover), 1)
            ),
            max_regret=max((r["regret"] for r in crossover), default=1.0),
        ),
    )
    with open(args.out, "w") as f:
        json.dump(artifact, f, indent=2)
    if not args.no_step_bench:
        # Serial vs overlapped train-step trajectory (was empty until the
        # compute/comm-overlap PR): its own root-level artifact so step-time
        # history is diffable independently of the probe sweep.
        step_artifact = _bench_overlap_step(repeats=max(2, repeats // 2))
        if step_artifact is not None:
            with open(args.step_out, "w") as f:
                json.dump(step_artifact, f, indent=2)
            print(f"[bench] step overlap trajectory -> {args.step_out} "
                  f"(regret {step_artifact['regret']:.3f})")
            # carry the per-issue dispatch fit into the calibration so
            # plan_pod_sync's overlap pricing sees the measured overhead
            calib.meta["dispatch_cost"] = (
                step_artifact["dispatch_cost_fit_us"] * 1e-6
            )
    if args.save_calibration:
        comm.save_calibration(calib, args.save_calibration)
        print(f"[bench] calibration -> {args.save_calibration}")

    s = artifact["summary"]
    print(f"[bench] {s['n_probes']} probes -> {args.out}")
    print(f"[bench] model |rel err|: preset="
          f"{s['mean_abs_rel_error_preset']:.2f} "
          f"fitted={s['mean_abs_rel_error_fitted']:.2f}")
    print(f"[bench] crossover agreement={s['crossover_agreement']:.2f} "
          f"mean_regret={s['mean_regret']:.2f}")


if __name__ == "__main__":
    main()
