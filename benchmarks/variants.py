"""Summarize tagged dry-run variants (the §Perf data provenance table)."""

from __future__ import annotations

import json
from pathlib import Path


def main(outdir: str = "results/dryrun") -> None:
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped") or not rec.get("ok"):
            continue
        c = rec["collectives"]
        rows.append((
            rec["arch"], rec["shape"], rec["mesh"], rec.get("tag", "") or "base",
            rec["memory"]["peak_per_device_bytes"] / 2**30,
            c.get("wire_bytes_bf16_corrected", c["wire_bytes_per_device"]) / 1e9,
            c["pod_crossing_bytes_total"] / 1e9,
            rec.get("meta", {}),
        ))
    print(f"{'arch':21s} {'shape':12s} {'mesh':6s} {'variant':9s} "
          f"{'mem GiB':>8s} {'wire GB':>9s} {'cross GB':>9s}")
    for a, s, m, t, mem, w, x, meta in rows:
        if t == "base":
            continue
        # find the base row
        base = next((r for r in rows if r[:3] == (a, s, m) and r[3] == "base"),
                    None)
        bw = f"{base[5]:9.1f}" if base else "        -"
        print(f"{a:21s} {s:12s} {m:6s} {t:9s} {mem:8.2f} {w:9.1f} {x:9.1f}"
              f"   (base wire {bw})")


if __name__ == "__main__":
    main()
