"""The paper's analyses as executable tables.

One function per claim/analysis in the paper; each returns a list of
(name, value, derived) rows for the CSV printer in run.py.
"""

from __future__ import annotations

import math
import time

from repro import comm
from repro.core import schedules as S
from repro.core.simulator import simulate_async, simulate_rounds
from repro.core.topology import (
    V5E_CHIPS_PER_POD,
    paper_smp_cluster,
    tpu_v5e_cluster,
)


def _t(fn, *a, **k):
    t0 = time.perf_counter()
    out = fn(*a, **k)
    return out, (time.perf_counter() - t0) * 1e6


def table_c1_broadcast_intra_machine():
    """C1: intra-machine broadcast is O(1) writes vs O(log n) messages."""
    rows = []
    for cores in [2, 4, 8, 16, 32]:
        topo = paper_smp_cluster(n_machines=1, cores=cores, nics=1)
        flat = S.build(topo, "broadcast", "flat", 4096.0)
        hier = S.build(topo, "broadcast", "hier_par", 4096.0)
        rows.append((
            f"c1_bcast_cores{cores}",
            simulate_rounds(hier) * 1e6,
            f"hier_rounds={hier.n_rounds};flat_rounds={flat.n_rounds};"
            f"expected_flat={math.ceil(math.log2(cores))}",
        ))
    return rows


def table_c2_gather_asymmetry():
    """C2: gather is not inverse broadcast; rounds and cost per direction."""
    rows = []
    for m in [1024.0, 65536.0, 1048576.0]:
        topo = paper_smp_cluster(n_machines=5, cores=4, nics=4)
        bc = S.build(topo, "broadcast", "hier_par", m)
        ga = S.build(topo, "gather", "hier_par", m)
        rows.append((
            f"c2_asym_m{int(m)}",
            simulate_rounds(ga) * 1e6,
            f"bcast_us={simulate_rounds(bc)*1e6:.1f};"
            f"bcast_rounds={bc.n_rounds};gather_rounds={ga.n_rounds}",
        ))
    return rows


def table_c3_heuristics():
    """C3/Rule 3: parallel egress vs single-leader hierarchical broadcast."""
    rows = []
    for M, d in [(9, 2), (27, 8), (64, 8)]:
        topo = paper_smp_cluster(n_machines=M, cores=max(d, 4), nics=d)
        seq = simulate_rounds(S.build(topo, "broadcast", "hier_seq", 4096.0))
        par = simulate_rounds(S.build(topo, "broadcast", "hier_par", 4096.0))
        rows.append((
            f"c3_bcast_M{M}_d{d}",
            par * 1e6,
            f"hier_seq_us={seq*1e6:.1f};speedup={seq/par:.2f}x",
        ))
    return rows


def table_c4_alltoall_gain():
    """C4 anchor: Kumar et al. measured ~55% all-to-all improvement; the
    model reproduces a gain of that magnitude in the consolidation regime."""
    rows = []
    topo = paper_smp_cluster(n_machines=8, cores=4, nics=2)
    for m in [64.0, 512.0, 4096.0, 65536.0, 1048576.0]:
        flat = simulate_rounds(S.build(topo, "all_to_all", "flat", m))
        hier = simulate_rounds(S.build(topo, "all_to_all", "hier_par", m))
        rows.append((
            f"c4_a2a_m{int(m)}",
            hier * 1e6,
            f"flat_us={flat*1e6:.1f};gain={100*(1-hier/flat):.1f}%",
        ))
    return rows


def table_model_vs_async():
    """Round-based model vs dependency-driven simulation (model validation)."""
    rows = []
    topo = paper_smp_cluster(n_machines=8, cores=4, nics=2)
    for coll, strat in [("broadcast", "hier_par"), ("gather", "hier_par"),
                        ("all_reduce", "hier_par"), ("all_reduce", "hier_par_bw"),
                        ("all_to_all", "hier_par"), ("all_gather", "hier_par")]:
        sched = S.build(topo, coll, strat, 65536.0)
        tr = simulate_rounds(sched)
        ta = simulate_async(sched)
        rows.append((
            f"model_{coll}_{strat}",
            tr * 1e6,
            f"async_us={ta*1e6:.1f};ratio={ta/tr:.3f}",
        ))
    return rows


def table_planner_tpu():
    """Planner decisions on the production TPU topology (2 pods), through
    the registry-backed ``comm.CommContext`` surface."""
    rows = []
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    for coll in comm.collectives():
        for nbytes in [1e4, 1e6, 1e8, 4e9]:
            t0 = time.perf_counter()
            plans = ctx.plans(coll, nbytes, lossy_ok=(coll == "all_reduce"))
            us = (time.perf_counter() - t0) * 1e6
            best, worst = plans[0].plan, plans[-1].plan
            runnable = "y" if plans[0].executable else "model-only"
            rows.append((
                f"plan_{coll}_{nbytes:.0e}",
                us,
                f"best={best.strategy};impl={best.impl};runnable={runnable};"
                f"t={best.t_rounds*1e3:.3f}ms;"
                f"vs_worst={worst.t_rounds/best.t_rounds:.1f}x",
            ))
    return rows


def table_gradsync_scenarios():
    """End-to-end gradient-sync planning for the assigned archs' grad sizes
    (f32 bytes), 2-pod cluster: the paper's model vs the flat baseline.

    Uses ``CommContext.plan`` (executable strategies only) so every row's
    choice is one the trainer can actually run, and reports the wire format
    ``comm.select_pod_sync`` would hand the train step."""
    rows = []
    ctx = comm.CommContext(tpu_v5e_cluster(n_pods=2))
    from repro.configs import ARCH_IDS, get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        gbytes = cfg.param_count() * 4.0 / V5E_CHIPS_PER_POD  # per-chip shard
        best = ctx.plan("all_reduce", gbytes, lossy_ok=True).plan
        flat = next(
            pc.plan for pc in ctx.plans("all_reduce", gbytes, lossy_ok=True)
            if pc.plan.strategy == "flat"
        )
        sync = comm.select_pod_sync(2, gbytes)
        rows.append((
            f"gradsync_{arch}",
            best.t_rounds * 1e6,
            f"strategy={best.strategy};pod_sync={sync};"
            f"flat_ms={flat.t_rounds*1e3:.2f};"
            f"speedup={flat.t_rounds/best.t_rounds:.1f}x",
        ))
    return rows


ALL_TABLES = [
    table_c1_broadcast_intra_machine,
    table_c2_gather_asymmetry,
    table_c3_heuristics,
    table_c4_alltoall_gain,
    table_model_vs_async,
    table_planner_tpu,
    table_gradsync_scenarios,
]
