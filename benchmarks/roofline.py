"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md SRoofline).

Per (arch x shape x mesh) cell:

  compute term    = analytic model FLOPs / (chips * 197 TF/s)
                    (XLA's cost_analysis undercounts while-loop bodies, so
                    the compute term uses the standard analytic accounting;
                    the HLO number is reported alongside.)
  memory term     = HLO bytes-accessed / (chips * 819 GB/s)   [CPU upper
                    bound: bf16 temps are stored f32 on CPU]
  collective term = per-device wire bytes / 50 GB/s ICI (assignment formula)
                    raw + bf16-corrected; multi-pod adds the two-tier DCN
                    term crossing/(pods*64 NICs*25GB/s) per the paper model.

Dominant term => the bottleneck; MODEL_FLOPS/HLO_FLOPs*chips => useful ratio.
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core.topology import (
    V5E_DCN_BW_PER_HOST,
    V5E_HBM_BW,
    V5E_HOSTS_PER_POD,
    V5E_ICI_BW,
    V5E_PEAK_FLOPS,
)

CHIPS = {"single": 256, "multi": 512}
PODS = {"single": 1, "multi": 2}


def model_flops(arch: str, shape_name: str, accum_meta: dict | None = None) -> float:
    """Analytic model FLOPs for one step of this cell (whole cluster)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    N = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim

    n_attn = 0 if cfg.family == "ssm" else (
        L // max(cfg.attn_every, 1) if cfg.family == "hybrid" else L
    )
    # causal self-attention fwd FLOPs per layer: qk + av, halved by causality
    attn_fwd = 2.0 * B * H * Dh * S * S
    if shape.kind == "train":
        tokens = B * S
        flops = 6.0 * N * tokens + 3.0 * n_attn * attn_fwd  # fwd + 2x bwd
        if cfg.family == "encdec":
            # encoder self-attn (non-causal, 2x) + decoder cross-attn
            flops += 3.0 * cfg.n_enc_layers * 2 * attn_fwd
            flops += 3.0 * L * 2 * attn_fwd
        return flops
    if shape.kind == "prefill":
        tokens = B * S
        flops = 2.0 * N * tokens + n_attn * attn_fwd
        if cfg.family == "encdec":
            flops += cfg.n_enc_layers * 2 * attn_fwd + L * 2 * attn_fwd
        return flops
    # decode: one token against an S-long cache
    flops = 2.0 * N * B
    if cfg.family == "hybrid":
        W = min(S, 4096)
        flops += 4.0 * (L // cfg.attn_every) * H * Dh * W * B
    elif cfg.family != "ssm":
        flops += 4.0 * L * H * Dh * S * B
    return flops


def load_cells(outdir: str = "results/dryrun", tag: str = "") -> list[dict]:
    rows = []
    for f in sorted(Path(outdir).glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag", "") != tag:
            continue
        if rec.get("skipped") or not rec.get("ok"):
            rows.append(rec)
            continue
        rows.append(analyse(rec))
    return rows


def analyse(rec: dict) -> dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = CHIPS[mesh]
    pods = PODS[mesh]
    mf = model_flops(arch, shape)
    t_compute = mf / (chips * V5E_PEAK_FLOPS)
    hlo_flops = rec["cost"]["flops"] * chips  # cost_analysis is per-partition
    bytes_acc = rec["cost"]["bytes_accessed"]
    t_memory = bytes_acc / V5E_HBM_BW          # per device already
    coll = rec["collectives"]
    wire = coll["wire_bytes_per_device"]
    wire_c = coll.get("wire_bytes_bf16_corrected", wire)
    t_coll_raw = wire / V5E_ICI_BW
    t_coll = wire_c / V5E_ICI_BW
    t_dcn = 0.0
    if pods > 1:
        t_dcn = coll["pod_crossing_bytes_total"] / (
            pods * V5E_HOSTS_PER_POD * V5E_DCN_BW_PER_HOST
        )
    terms = {"compute": t_compute, "memory": t_memory / 2,  # bf16-on-TPU est.
             "collective": t_coll, "dcn": t_dcn}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    rec["roofline"] = {
        "model_flops": mf,
        "hlo_flops_total": hlo_flops,
        "useful_ratio": mf / hlo_flops if hlo_flops else 0.0,
        "t_compute_s": t_compute,
        "t_memory_s_raw": t_memory,
        "t_memory_s": t_memory / 2,
        "t_collective_s_raw": t_coll_raw,
        "t_collective_s": t_coll,
        "t_dcn_s": t_dcn,
        "dominant": dominant,
        "roofline_fraction": t_compute / step_time if step_time else 0.0,
    }
    return rec


def table(rows: list[dict]) -> str:
    hdr = (f"{'arch':21s} {'shape':12s} {'mesh':6s} {'mem/dev':>8s} "
           f"{'t_comp':>8s} {'t_mem':>8s} {'t_coll':>8s} {'t_dcn':>8s} "
           f"{'domin.':>7s} {'frac':>5s} {'useful':>6s}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("skipped"):
            out.append(f"{r['arch']:21s} {r['shape']:12s} {r['mesh']:6s} "
                       f"{'SKIP':>8s}  ({r['reason'][:60]})")
            continue
        if not r.get("ok"):
            out.append(f"{r['arch']:21s} {r['shape']:12s} {r['mesh']:6s} FAIL")
            continue
        rf = r["roofline"]
        mem = r["memory"]["peak_per_device_bytes"] / 2**30
        out.append(
            f"{r['arch']:21s} {r['shape']:12s} {r['mesh']:6s} {mem:7.1f}G "
            f"{rf['t_compute_s']*1e3:7.1f}m {rf['t_memory_s']*1e3:7.1f}m "
            f"{rf['t_collective_s']*1e3:7.1f}m {rf['t_dcn_s']*1e3:7.1f}m "
            f"{rf['dominant'][:7]:>7s} {rf['roofline_fraction']:5.2f} "
            f"{rf['useful_ratio']:6.2f}"
        )
    return "\n".join(out)


def main() -> None:
    rows = load_cells()
    print(table(rows))
    Path("results").mkdir(exist_ok=True)
    Path("results/roofline.txt").write_text(table(rows))
    # csv for EXPERIMENTS.md
    import csv

    with open("results/roofline.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["arch", "shape", "mesh", "mem_gib", "t_compute_ms",
                    "t_memory_ms", "t_collective_ms", "t_dcn_ms",
                    "dominant", "roofline_fraction", "useful_ratio",
                    "skipped"])
        for r in rows:
            if r.get("skipped") or not r.get("ok"):
                w.writerow([r["arch"], r["shape"], r["mesh"]] + [""] * 8 +
                           [r.get("reason", r.get("error", ""))[:80]])
                continue
            rf = r["roofline"]
            w.writerow([
                r["arch"], r["shape"], r["mesh"],
                round(r["memory"]["peak_per_device_bytes"] / 2**30, 2),
                round(rf["t_compute_s"] * 1e3, 3),
                round(rf["t_memory_s"] * 1e3, 3),
                round(rf["t_collective_s"] * 1e3, 3),
                round(rf["t_dcn_s"] * 1e3, 3),
                rf["dominant"],
                round(rf["roofline_fraction"], 3),
                round(rf["useful_ratio"], 3),
                "",
            ])
    print("\nwrote results/roofline.{txt,csv}")


if __name__ == "__main__":
    main()
