"""Kernel micro-benchmarks: wall time of the compiled CPU paths (jnp) and
interpret-mode correctness deltas vs ref -- correctness-grade numbers on
this box; real perf comes from the roofline terms.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _timeit(f, *args, n=5):
    out = f(*args)
    if isinstance(out, tuple):
        out[0].block_until_ready()
    else:
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n * 1e6


def bench_attention():
    from repro.kernels.flash_attention import ops, ref

    rows = []
    rng = np.random.RandomState(0)
    for (B, S, H, Hkv, Dh) in [(1, 512, 8, 2, 64), (2, 1024, 8, 8, 64)]:
        q = jnp.asarray(rng.randn(B, S, H, Dh), jnp.float32)
        k = jnp.asarray(rng.randn(B, S, Hkv, Dh), jnp.float32)
        v = jnp.asarray(rng.randn(B, S, Hkv, Dh), jnp.float32)
        chunked = jax.jit(lambda q, k, v: ops._chunked_mha(q, k, v, True, 0.0, 0))
        us = _timeit(chunked, q, k, v)
        want = ref.mha_reference(q, k, v)
        err = float(jnp.max(jnp.abs(chunked(q, k, v) - want)))
        rows.append((f"attn_chunked_B{B}_S{S}_H{H}", us, f"max_err={err:.1e}"))
    return rows


def bench_rmsnorm():
    from repro.kernels.rmsnorm import ops, ref

    rows = []
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(64, 512, 1024), jnp.float32)
    w = jnp.asarray(rng.randn(1024), jnp.float32)
    f = jax.jit(lambda x, w: ops.rmsnorm(x, w))
    us = _timeit(f, x, w)
    err = float(jnp.max(jnp.abs(f(x, w) - ref.rmsnorm_reference(x, w))))
    rows.append(("rmsnorm_64x512x1024", us, f"max_err={err:.1e}"))
    return rows


def bench_ssm():
    from repro.kernels.ssm_scan import ops, ref

    rows = []
    rng = np.random.RandomState(0)
    B, S, H, P, N = 2, 512, 8, 64, 64
    x = jnp.asarray(rng.randn(B, S, H, P), jnp.float32)
    dt = jnp.asarray(np.abs(rng.randn(B, S, H)) * 0.1 + 0.01, jnp.float32)
    A = jnp.asarray(-np.abs(rng.randn(H)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    Cm = jnp.asarray(rng.randn(B, S, N), jnp.float32)
    D = jnp.asarray(rng.randn(H), jnp.float32)
    chunked = jax.jit(lambda *a: ops._chunked_jnp(*a))
    seq = jax.jit(lambda *a: ref.selective_scan_reference(*a))
    us_c = _timeit(chunked, x, dt, A, Bm, Cm, D)
    us_s = _timeit(seq, x, dt, A, Bm, Cm, D)
    err = float(jnp.max(jnp.abs(chunked(x, dt, A, Bm, Cm, D)
                                - seq(x, dt, A, Bm, Cm, D))))
    rows.append((f"ssm_chunked_S{S}", us_c,
                 f"sequential_us={us_s:.0f};speedup={us_s/us_c:.1f}x;err={err:.1e}"))
    return rows


ALL_BENCHES = [bench_attention, bench_rmsnorm, bench_ssm]
